//! Discrete-event PDC serving simulation (paper §4.1 end-to-end).
//!
//! Glues the coordinator components over the substrate models: requests
//! arrive (workload), are routed (router) to prefill instances (prefill),
//! reuse cached prefixes (cache::context over mempool), transfer KV over
//! the RDMA plane (transfer), and decode in a *pool* of LEP instances
//! (decode) behind a decode-side placement policy, under SLO-adaptive,
//! SLO-tiered batching (batcher). Time is virtual (µs); engine latencies
//! come from the calibrated simnpu/netsim models.
//!
//! ## Elastic PDC (paper §4.1 "Dynamic Adjustment", §6.2.2)
//!
//! With [`SimOptions::autoscale`] set, the [`Autoscaler`] controller is in
//! the loop as a periodic `ScaleEpoch` event: each epoch collects
//! [`WorkloadStats`] from the window's arrivals/emissions plus live queue
//! depths and slot occupancy, asks the controller for an [`ElasticAction`],
//! and enacts it. A [`SplitPlan`] drains prefill instances into the decode
//! pool or pulls decode NPUs up as new prefill instances; moved NPUs are
//! offline for a modeled *role-switch latency* (weight reload through the
//! shared model cache — the Table 2 EMS warm-switch path), and every move
//! is logged as a [`ResplitEvent`] in the final [`ServingReport`].
//!
//! ## §6.2.1 attention offloading as a first-class elastic action
//!
//! When decode is memory-bound (long KV, saturated batch) and the prefill
//! pool has measured idle NPU-seconds, the controller prefers an
//! `Offload` over a resplit: a fraction of the decode FA core runs on
//! *donor* prefill instances (Adrenaline-style). While engaged:
//!
//! * decode steps use the offloaded per-layer latency from
//!   [`offload::model_offload`] (never slower than the local step — the
//!   remote share runs concurrently),
//! * donor instances stay admissible for prefill but pay the modeled
//!   HBM-bandwidth tax on every batch (accounted as `donor_tax_us`),
//! * the router tracks donors as a first-class
//!   [`crate::coordinator::router::InstanceState`] so recovery re-homing
//!   prefers non-donor instances.
//!
//! Faults thread through: donors lost at a detection heartbeat force ONE
//! `Recall` before that sweep's re-homing — decode pulls the FA core back
//! locally and pays a transient TPOT degradation window
//! ([`RECALL_SPIKE_FACTOR`] for [`RECALL_SPIKE_US`] scaled by the lost
//! donor share) instead of stalling; a graceful recall (pressure resolved
//! / resplit preempts) costs nothing. Every transition lands in the
//! report's [`OffloadEvent`] log.
//!
//! ## Failure domains (correlated chaos) and planned placement
//!
//! The sim owns a [`crate::domains::ResilienceController`]: the
//! [`crate::domains::FailureDomainMap`] laying the deployment out over
//! nested physical domains (node → rack/PSU → UB plane) plus the
//! [`crate::domains::ResiliencePolicy`] in force. The layout itself is
//! *chosen* by the [`crate::domains::PlacementPlanner`] under the serving
//! config's [`crate::config::PlacementObjective`]: `Packed` (the default)
//! reproduces the historical contiguous layout bit-for-bit; the spread
//! objectives bound blast radius at a priced locality cost — every
//! prefill batch and decode step is multiplied by the planner's
//! per-component cross-rack tax (exactly 1.0 under `Packed`).
//!
//! Flows are *plane-attributed*: KV pushes, UB pool fetches, and the
//! dispatch/combine share of steps/batches are homed on their component's
//! UB sub-plane ([`FailureDomainMap::ub_plane`] of the home node). A
//! [`FaultKind::PlaneBrownout`] opens a plane-scoped
//! [`DegradationMap`] window that degrades only flows homed on the lost
//! plane (with a single configured plane it degenerates to the legacy
//! whole-fabric window); the extra time is accounted per plane in
//! [`ServingReport::plane_exposure_us`]. A
//! [`FaultKind::RackLoss`] expands against the map at injection (member
//! instances crash, member pool servers fail, rack links degrade in the
//! per-(plane, node-pair) [`DegradationMap`]); with the domain-aware
//! policy, detection runs the **incident → mass recall → overlapped
//! re-home → backfill** state machine (see `coordinator/README.md`):
//! §6.2.1 donors are spread across racks at engagement, a domain-wide
//! incident recalls the offload once with a share-scaled spike, and each
//! crashed decode instance is backfilled by a borrowed prefill NPU group
//! (a logged loan [`ResplitEvent`]) until its replacement warm-loads.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

use crate::cache::ContextCache;
use crate::config::{Config, UB_PLANES};
use crate::coordinator::autoscale::{
    offload, Autoscaler, ElasticAction, OffloadSignals, RecallReason, SplitPlan, WorkloadStats,
};
use crate::coordinator::batcher::{plan_for_slo, AdmissionQueue};
use crate::coordinator::decode::{DecodeInstance, Slot};
use crate::coordinator::eplb;
use crate::coordinator::prefill::{batch_latency_us, PrefillInstance};
use crate::coordinator::request::{RequestPhase, RequestState};
use crate::coordinator::router::{InstanceState, Router, RouterKind};
use crate::coordinator::transfer::{kv_transfer, TransferCost, TransferScheduler};
use crate::domains::{
    FailureDomainMap, PlacementPlanner, PlacementReport, ResilienceController, ResiliencePolicy,
};
use crate::faults::{FaultKind, FaultOptions, FaultRecord};
use crate::mempool::{Key, MemPool, NamespaceId};
use crate::metrics::{
    Histogram, OffloadEvent, OffloadEventKind, ResplitEvent, Role, ServingReport, TierAttainment,
};
use crate::netsim::{DegradationMap, LinkDegradation, LinkKey, Plane};
use crate::simnpu::pipeline::{DecodePoint, STEP_OVERHEAD_US};
use crate::util::split_even;
use crate::workload::{ExpertActivation, Request};
use crate::Micros;

/// Transient TPOT degradation window after a *forced* (donor-failure)
/// offload recall: the decode side re-stages the FA working set locally
/// and re-plans its batches, so every step inside the window runs this
/// factor slower. Graceful recalls pay nothing.
pub const RECALL_SPIKE_FACTOR: f64 = 1.25;
/// Length of the post-recall degradation window, µs.
pub const RECALL_SPIKE_US: Micros = 2e6;

/// Decode-side placement policy for the instance pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodePlacement {
    /// Send each transfer-complete request to the instance with the lowest
    /// (active + queued) / capacity ratio.
    LeastLoaded,
    /// Rotate across instances regardless of load.
    RoundRobin,
}

/// Elastic-autoscaling knobs (see module docs).
#[derive(Debug, Clone)]
pub struct AutoscaleOptions {
    /// Controller epoch length, µs.
    pub interval_us: f64,
    /// Role-switch latency, µs: the time a moved NPU group is offline
    /// between roles (engine teardown + weight reload). Defaults to the
    /// model-cache warm-switch latency ([`default_switch_latency_us`]).
    pub switch_latency_us: f64,
    /// Floor on decode-pool NPUs; 0 derives `max(quantum, decode_npus/4)`
    /// from the deployment, rounded so the prefill side stays
    /// instance-quantized.
    pub min_decode_npus: usize,
    /// Controller hysteresis (don't move below this current:ideal ratio).
    pub hysteresis: f64,
    /// §6.2.1 attention offloading as an elastic action (on by default;
    /// `--no-offload` runs the resplit-only ablation).
    pub offload: bool,
}

impl Default for AutoscaleOptions {
    fn default() -> Self {
        AutoscaleOptions {
            interval_us: 1e6,
            switch_latency_us: default_switch_latency_us(),
            min_decode_npus: 0,
            hysteresis: 1.15,
            offload: true,
        }
    }
}

/// Live state of an engaged §6.2.1 attention offload.
#[derive(Debug, Clone)]
struct ActiveOffload {
    /// Fraction of the decode FA core running on donors.
    frac: f64,
    /// Donor prefill instance slots (router state `Donor`).
    donors: Vec<usize>,
    /// Donor prefill throughput retained (modeled at engagement).
    prefill_retained: f64,
    /// Virtual time the offload engaged.
    engaged_us: Micros,
}

/// Modeled role-switch latency: a role change is an engine restart on a new
/// graph, so the dominant cost is streaming the (already pool-resident)
/// weights back into NPU memory — the Table 2 EMS warm model-switch path
/// (§4.4.3), ~5 s for the 671 GB model.
pub fn default_switch_latency_us() -> Micros {
    let net = crate::netsim::NetSim::default();
    let row = crate::cache::model::table2_row(
        &net,
        &crate::cache::model::Table2Params::default(),
        crate::cache::LoadStrategy::Ems,
    );
    row.switch_latency_s * 1e6
}

/// Simulation options beyond the base [`Config`].
#[derive(Debug, Clone)]
pub struct SimOptions {
    pub router: RouterKind,
    /// Prefill batch budget, tokens per NPU (paper: 16 K).
    pub prefill_tokens_per_npu: usize,
    /// Hard cap on simulated events (runaway guard).
    pub max_events: usize,
    pub seed: u64,
    /// Number of decode instances the decode NPUs are split across.
    pub decode_instances: usize,
    /// Placement policy over the decode pool.
    pub placement: DecodePlacement,
    /// Elastic PDC: wire the autoscaler into the event loop. `None` runs
    /// the classic frozen split.
    pub autoscale: Option<AutoscaleOptions>,
    /// Chaos: inject a [`crate::faults::FaultPlan`] and (optionally)
    /// orchestrate recovery. `None` runs the healthy system.
    pub faults: Option<FaultOptions>,
    /// Domain-aware resilience behaviors (donor spreading, decode
    /// backfill, mass recall). The default `independent()` policy
    /// reproduces the plain per-fault recovery orchestration.
    pub resilience: ResiliencePolicy,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            router: RouterKind::PeerToPeer,
            prefill_tokens_per_npu: 16384,
            max_events: 2_000_000,
            seed: 0,
            decode_instances: 1,
            placement: DecodePlacement::LeastLoaded,
            autoscale: None,
            faults: None,
            resilience: ResiliencePolicy::independent(),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Event {
    Arrival(usize),
    PrefillKick(usize),
    /// Batch completion on slot `.0`, valid only for batch epoch `.1` —
    /// a crash discards the in-flight batch and bumps the slot's epoch, so
    /// the stale completion of the dead batch can never terminate a
    /// replacement batch early.
    PrefillDone(usize, u64),
    TransferDone(u64),
    DecodeStep(usize),
    /// Autoscaler epoch: collect stats, recommend, enact.
    ScaleEpoch,
    /// A converted NPU group finishes its role switch into prefill slot i.
    PrefillUp(usize),
    /// Prefill slot i's drained NPU group finishes its switch into decode.
    DecodeUp(usize),
    /// Fault i of the plan takes hardware effect (chaos runs).
    Fault(usize),
    /// Failure-detection heartbeat epoch (chaos runs).
    Heartbeat,
    /// The replacement NPU group for fault record i (a decode crash)
    /// finishes its warm model load and rejoins the pool.
    DecodeRecover(usize),
    /// The replacement NPU group for fault record i (a prefill crash)
    /// finishes its warm model load and resumes serving.
    PrefillRecover(usize),
}

/// Heap entry ordered by virtual time.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Timed {
    t: Micros,
    seq: u64,
    ev: Event,
}

impl Eq for Timed {}

impl Ord for Timed {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for Timed {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The assembled serving simulation.
pub struct ServeSim {
    pub cfg: Config,
    pub opts: SimOptions,
    pub requests: Vec<RequestState>,
    router: Router,
    prefills: Vec<PrefillInstance>,
    /// Prefill slots mid-role-switch (decode→prefill conversion pending).
    pf_pending_up: Vec<bool>,
    /// Prefill slots draining toward decode (NPUs promised away; the slot
    /// may not be re-activated until its `DecodeUp` completes).
    pf_draining: Vec<bool>,
    decodes: Vec<DecodeInstance>,
    decode_queues: Vec<AdmissionQueue>,
    decode_step_pending: Vec<bool>,
    /// SLO-derived decode batch per NPU, per tier (tier 0 = base SLO).
    tier_batch_per_npu: Vec<usize>,
    rr_next: usize,
    transfers: TransferScheduler,
    pool: MemPool,
    context_cache: Option<ContextCache>,
    /// Per-prefill-instance batch in flight: (requests, completion handled
    /// at PrefillDone).
    inflight_batches: Vec<Option<crate::coordinator::prefill::PrefillBatch>>,
    /// Global residual EPLB imbalance measured at init for the full
    /// deployment (prefill engines and SLO planning use this).
    eplb_imbalance: f64,
    /// Per-decode-instance residual imbalance, recomputed whenever a
    /// resplit changes an instance's EP degree (ROADMAP: elastic moves pay
    /// the real EPLB cost).
    decode_eplb: Vec<f64>,
    /// The measured expert-activation histogram the imbalances derive from.
    expert_hist: Vec<u64>,
    /// npus → imbalance memo (resplits revisit the same sizes).
    eplb_cache: BTreeMap<usize, f64>,
    heap: BinaryHeap<Reverse<Timed>>,
    seq: u64,
    now: Micros,
    // --- elastic state ---
    autoscaler: Option<Autoscaler>,
    scale_interval_us: Micros,
    switch_latency_us: Micros,
    /// Committed (post-enactment) prefill NPU target the controller sees.
    target_prefill_npus: usize,
    win_prompt_tokens: u64,
    win_output_tokens: u64,
    resplits: Vec<ResplitEvent>,
    /// NPU-seconds integration.
    acc_prefill_npu_us: f64,
    acc_decode_npu_us: f64,
    last_npu_t: Micros,
    // --- §6.2.1 offload state ---
    /// Whether the controller may choose `Offload` actions at all.
    offload_enabled: bool,
    /// The engaged offload, if any.
    offload: Option<ActiveOffload>,
    offload_events: Vec<OffloadEvent>,
    /// Integrated virtual time offload was engaged.
    offload_active_us: f64,
    /// Accumulated extra prefill batch latency paid by donors.
    donor_tax_us: f64,
    /// Accumulated extra decode step time inside recall-spike windows.
    recall_spike_us: f64,
    /// Post-recall TPOT degradation window (donor-failure recalls).
    recall_spike: LinkDegradation,
    /// Busy (executing) NPU-µs per role — idle = assigned − busy.
    acc_prefill_busy_npu_us: f64,
    acc_decode_busy_npu_us: f64,
    /// Prefill busy NPU-µs accumulated in the current controller window,
    /// and the assigned-integral mark at the window's start — together
    /// they yield the measured per-window prefill idle fraction.
    win_prefill_busy_npu_us: f64,
    win_prefill_assigned_mark: f64,
    // --- chaos state ---
    /// Failure-detection heartbeat period (0 = no chaos).
    hb_us: Micros,
    /// Whether recovery orchestration is enabled (false = baseline).
    recovery_enabled: bool,
    /// Replacement warm model-load latency (Table 2).
    recovery_latency_us: Micros,
    /// Prefill slots whose NPU group crashed (hardware view; the router's
    /// failed mask follows at detection).
    pf_failed: Vec<bool>,
    /// Per-slot batch epoch: bumped whenever an in-flight batch is
    /// discarded by a crash, invalidating its pending `PrefillDone`.
    pf_epoch: Vec<u64>,
    /// Decode instances whose NPU group crashed.
    decode_failed: Vec<bool>,
    /// Per-decode-instance straggler window (step-latency multiplier).
    straggle: Vec<LinkDegradation>,
    /// Fabric degradation state (KV transfers + pool fetches): the legacy
    /// whole-fabric window plus per-(plane, node-pair) windows scoped by
    /// rack-loss cascades.
    links: DegradationMap,
    /// Failure-domain layout + the domain-aware recovery policy in force.
    resilience: ResilienceController,
    /// Scored layout report from the placement planner (this run's
    /// locality-vs-blast-radius trade).
    placement: PlacementReport,
    /// Per prefill-slot placement locality tax (≥ 1.0; exactly 1.0 under
    /// the default `Packed` objective).
    pf_tax: Vec<f64>,
    /// Per decode-instance placement locality tax.
    dec_tax: Vec<f64>,
    /// Extra virtual µs charged by UB sub-plane brown-out windows to flows
    /// homed on each plane (report: `plane_exposure_us`).
    plane_exposure_us: Vec<f64>,
    /// Prefill NPU groups on loan to the decode pool, backfilling crashed
    /// decode capacity until the replacement warm-loads.
    backfill_loans: Vec<BackfillLoan>,
    /// Record indices of crashes awaiting heartbeat detection.
    undetected: Vec<usize>,
    fault_records: Vec<FaultRecord>,
    /// Requests dropped by faults (recovery-disabled baseline).
    lost: usize,
    /// Pool namespace tracking each request's prompt-KV residency (chaos
    /// runs only): decides re-fetch vs re-prefill after a decode crash.
    kv_ns: Option<NamespaceId>,
    // --- metrics ---
    ttft: Histogram,
    tpot: Histogram,
    pub cache_fetch_us_total: f64,
    pub finished: usize,
    /// Peak prefill-queue imbalance observed across arrivals.
    pub peak_router_imbalance: f64,
    /// Prompt tokens recomputed because a KV-centric reroute forfeited
    /// the locally-cached prefix.
    pub recomputed_tokens: u64,
}

/// One prefill NPU group on loan to the decode pool (domain-aware
/// backfill): `slot` drained into decode to cover the capacity destroyed
/// by fault record `fault`, and returns to prefill when that fault's
/// replacement group warm-loads.
#[derive(Debug, Clone, Copy)]
struct BackfillLoan {
    slot: usize,
    fault: usize,
    /// The replacement arrived while the group was still mid role-switch:
    /// bounce it straight back to prefill when its `DecodeUp` fires.
    returning: bool,
}

/// Pool key under which a request's prompt-KV residency is tracked
/// (chaos runs): decides the re-fetch vs re-prefill recovery path.
fn chaos_kv_key(rid: u64) -> Key {
    Key::of_bytes(&rid.to_le_bytes())
}

/// Residual EPLB imbalance of a decode instance sized `npus` (2 dies/NPU =
/// `2·npus` EP ranks) under the measured activation histogram. Shrinking an
/// instance drops its EP degree below one-expert-per-rank, so experts pack
/// multiple-per-rank (LPT) and the residual imbalance grows — the real
/// EPLB cost an elastic resplit pays.
fn instance_eplb(hist: &[u64], npus: usize, redundant_budget: usize) -> f64 {
    if npus == 0 {
        return 1.0;
    }
    let ranks = npus * 2;
    let redundant = redundant_budget.min(ranks.saturating_sub(hist.len()));
    eplb::deployment_imbalance(hist, ranks, redundant).min(1.6)
}

impl ServeSim {
    pub fn new(cfg: Config, opts: SimOptions, trace: Vec<Request>) -> ServeSim {
        let s = &cfg.serving;
        let quantum = s.npus_per_prefill;
        let n_pf_initial = s.prefill_instances;

        // memory pool across all host CPUs of the deployment's nodes
        let pool_nodes = (s.total_npus() / cfg.topo.npus_per_node).max(2);
        let dram_per_server = 64u64 << 30;
        let ssd_per_server = 256u64 << 30;
        let mut pool = MemPool::new(pool_nodes, dram_per_server, ssd_per_server);

        let context_cache = if s.context_caching {
            Some(ContextCache::new(
                &mut pool,
                256,
                cfg.model.kv_bytes_per_token(),
                s.cache_over_ub,
            ))
        } else {
            None
        };

        // EPLB: measure skewed activation, place experts, derive imbalance
        let mut ea = ExpertActivation::new(opts.seed ^ 0xE9, cfg.model.n_routed_experts, 1.05);
        let hist = ea.batch_histogram(8192, cfg.model.top_k);
        let eplb_imbalance = instance_eplb(&hist, s.decode_npus, s.decode_redundant_experts);

        // per-tier SLO-adaptive decode batch caps (Table 5 mechanism)
        let base_point = DecodePoint {
            kv_len: 4096,
            ep: s.decode_ep_degree(),
            microbatch: s.microbatch,
            mtp: s.mtp,
            mtp_acceptance: s.mtp_acceptance,
            eplb_imbalance,
            batch_per_npu: 1,
        };
        let tier_batch_per_npu: Vec<usize> = (0..s.n_tiers())
            .map(|t| {
                plan_for_slo(&cfg.die, &cfg.model, &base_point, &s.slo_for_tier(t), 1)
                    .batch_per_npu
            })
            .collect();

        // the elastic controller (optional) and the prefill slot budget
        let (autoscaler, scale_interval_us, switch_latency_us) = match &opts.autoscale {
            Some(a) => {
                let total = s.total_npus();
                let raw_min_dec = if a.min_decode_npus > 0 {
                    a.min_decode_npus
                } else {
                    (s.decode_npus / 4).max(quantum)
                };
                // keep the prefill side instance-quantized at max scale-out
                let min_dec = total - (total.saturating_sub(raw_min_dec)) / quantum * quantum;
                let ctl = Autoscaler {
                    total_npus: total,
                    prefill_quantum: quantum,
                    min_prefill: quantum,
                    min_decode: min_dec,
                    hysteresis: a.hysteresis,
                };
                (Some(ctl), a.interval_us, a.switch_latency_us)
            }
            // no autoscaler: the switch latency still prices domain-aware
            // backfill loans (prefill groups borrowed into decode)
            None => (None, 0.0, default_switch_latency_us()),
        };
        let max_pf_slots = match &autoscaler {
            Some(c) => ((c.total_npus - c.min_decode) / quantum).max(n_pf_initial),
            None => n_pf_initial,
        };

        let prefills = (0..max_pf_slots).map(|i| PrefillInstance::new(i, quantum)).collect();
        let mut router = Router::new(opts.router, max_pf_slots);
        for idx in n_pf_initial..max_pf_slots {
            router.set_active(idx, false);
        }

        // decode pool: split the decode NPUs across the instances (never
        // more instances than NPUs — every instance needs capacity)
        let n_dec = opts.decode_instances.clamp(1, s.decode_npus.max(1));
        let batch0 = tier_batch_per_npu[0];
        let sizes = split_even(s.decode_npus, n_dec);
        let decodes: Vec<DecodeInstance> = sizes
            .iter()
            .copied()
            .enumerate()
            .map(|(i, npus)| {
                DecodeInstance::new(
                    npus,
                    batch0 * npus,
                    opts.seed ^ 0xD ^ (i as u64).wrapping_mul(0x9E37_79B9),
                )
            })
            .collect();
        // per-instance EPLB at the initial sizes (== the global value when
        // the pool is one full-size instance)
        let mut eplb_cache = BTreeMap::new();
        eplb_cache.insert(s.decode_npus, eplb_imbalance);
        let decode_eplb: Vec<f64> = sizes
            .iter()
            .map(|&n| {
                *eplb_cache
                    .entry(n)
                    .or_insert_with(|| instance_eplb(&hist, n, s.decode_redundant_experts))
            })
            .collect();

        // chaos wiring: detection/recovery knobs + the KV-residency
        // namespace that decides re-fetch vs re-prefill after a crash
        let (hb_us, recovery_enabled, recovery_latency_us) = match &opts.faults {
            Some(f) => (f.heartbeat_us, f.recovery, f.recovery_latency_us),
            None => (0.0, true, 0.0),
        };
        let kv_ns = opts
            .faults
            .as_ref()
            .map(|_| pool.controller.create_namespace("chaos-kv"));

        // failure-domain layout (node → rack/PSU) *planned* under the
        // serving config's placement objective (`Packed` reproduces the
        // historical contiguous layout bit-for-bit) + the domain-aware
        // policy in force; the plan also prices each component's marginal
        // cross-rack locality tax
        let plan = PlacementPlanner::new(&cfg.topo, cfg.serving.placement)
            .plan(&cfg.serving, max_pf_slots, n_dec);
        let resilience = ResilienceController::new(plan.map, opts.resilience);
        let placement = plan.report;
        let pf_tax = plan.prefill_tax;
        let dec_tax = plan.decode_tax;

        let target_prefill_npus = n_pf_initial * quantum;
        let mut sim = ServeSim {
            router,
            prefills,
            pf_pending_up: vec![false; max_pf_slots],
            pf_draining: vec![false; max_pf_slots],
            decode_queues: (0..n_dec).map(|_| AdmissionQueue::default()).collect(),
            decode_step_pending: vec![false; n_dec],
            decodes,
            tier_batch_per_npu,
            rr_next: 0,
            transfers: TransferScheduler::default(),
            pool,
            context_cache,
            inflight_batches: vec![None; max_pf_slots],
            eplb_imbalance,
            decode_eplb,
            expert_hist: hist,
            eplb_cache,
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
            autoscaler,
            scale_interval_us,
            switch_latency_us,
            target_prefill_npus,
            win_prompt_tokens: 0,
            win_output_tokens: 0,
            resplits: Vec::new(),
            acc_prefill_npu_us: 0.0,
            acc_decode_npu_us: 0.0,
            last_npu_t: 0.0,
            offload_enabled: opts.autoscale.as_ref().is_some_and(|a| a.offload),
            offload: None,
            offload_events: Vec::new(),
            offload_active_us: 0.0,
            donor_tax_us: 0.0,
            recall_spike_us: 0.0,
            recall_spike: LinkDegradation::default(),
            acc_prefill_busy_npu_us: 0.0,
            acc_decode_busy_npu_us: 0.0,
            win_prefill_busy_npu_us: 0.0,
            win_prefill_assigned_mark: 0.0,
            hb_us,
            recovery_enabled,
            recovery_latency_us,
            pf_failed: vec![false; max_pf_slots],
            pf_epoch: vec![0; max_pf_slots],
            decode_failed: vec![false; n_dec],
            straggle: vec![LinkDegradation::default(); n_dec],
            links: DegradationMap::default(),
            resilience,
            placement,
            pf_tax,
            dec_tax,
            plane_exposure_us: vec![0.0; UB_PLANES],
            backfill_loans: Vec::new(),
            undetected: Vec::new(),
            fault_records: Vec::new(),
            lost: 0,
            kv_ns,
            ttft: Histogram::new(),
            tpot: Histogram::new(),
            cache_fetch_us_total: 0.0,
            finished: 0,
            peak_router_imbalance: 1.0,
            recomputed_tokens: 0,
            requests: trace.into_iter().map(RequestState::new).collect(),
            cfg,
            opts,
        };
        for i in 0..sim.requests.len() {
            let t = sim.requests[i].spec.arrival_us;
            sim.push(t, Event::Arrival(i));
        }
        if sim.autoscaler.is_some() {
            let t = sim.scale_interval_us;
            sim.push(t, Event::ScaleEpoch);
        }
        // chaos: schedule every planned fault, plus the detection heartbeat
        let fault_times: Vec<(Micros, usize)> = sim
            .opts
            .faults
            .as_ref()
            .map(|f| f.plan.events.iter().enumerate().map(|(i, e)| (e.t_us, i)).collect())
            .unwrap_or_default();
        let any_faults = !fault_times.is_empty();
        for (t, i) in fault_times {
            sim.push(t, Event::Fault(i));
        }
        if any_faults {
            let t = sim.hb_us;
            sim.push(t, Event::Heartbeat);
        }
        sim
    }

    fn push(&mut self, t: Micros, ev: Event) {
        self.seq += 1;
        self.heap.push(Reverse(Timed { t, seq: self.seq, ev }));
    }

    /// Run to completion (or the event cap). Returns the serving report.
    pub fn run(&mut self) -> ServingReport {
        let mut events = 0usize;
        while let Some(Reverse(Timed { t, ev, .. })) = self.heap.pop() {
            // Once every request is terminally accounted, serving is over:
            // remaining planned faults would hit an empty system with no
            // heartbeat left to detect them, and pending replacements or
            // in-flight role switches (elastic resplits, backfill-loan
            // returns) are pure bookkeeping. None may advance virtual time
            // — they would inflate the reported duration (and deflate
            // goodput/s).
            if !self.requests.is_empty() && self.finished + self.lost >= self.requests.len() {
                match ev {
                    Event::Fault(_) | Event::Heartbeat => continue,
                    Event::PrefillUp(inst) => {
                        self.integrate_npu_time();
                        self.pf_pending_up[inst] = false;
                        self.router.set_active(inst, true);
                        continue;
                    }
                    Event::DecodeUp(inst) => {
                        self.integrate_npu_time();
                        self.pf_draining[inst] = false;
                        // a loan already flagged for return dissolves here
                        // — serving is over, no NPUs move
                        self.backfill_loans.retain(|l| !(l.slot == inst && l.returning));
                        continue;
                    }
                    Event::DecodeRecover(rec) => {
                        if let FaultKind::DecodeCrash { instance } =
                            self.fault_records[rec].kind
                        {
                            self.integrate_npu_time();
                            self.fault_records[rec].recovered_us = Some(t);
                            self.decode_failed[instance] = false;
                        }
                        // the replacement obsoletes any backfill loan;
                        // serving is over, so the loan just dissolves
                        self.backfill_loans.retain(|l| l.fault != rec);
                        continue;
                    }
                    Event::PrefillRecover(rec) => {
                        if let FaultKind::PrefillCrash { instance } =
                            self.fault_records[rec].kind
                        {
                            self.integrate_npu_time();
                            self.fault_records[rec].recovered_us = Some(t);
                            self.pf_failed[instance] = false;
                            self.router.set_failed(instance, false);
                        }
                        continue;
                    }
                    _ => {}
                }
            }
            self.now = t;
            events += 1;
            if events > self.opts.max_events {
                eprintln!("warning: event cap reached at t={t}");
                break;
            }
            match ev {
                Event::Arrival(idx) => self.on_arrival(idx),
                Event::PrefillKick(inst) => self.kick_prefill(inst),
                Event::PrefillDone(inst, epoch) => self.on_prefill_done(inst, epoch),
                Event::TransferDone(req) => self.on_transfer_done(req),
                Event::DecodeStep(inst) => self.on_decode_step(inst),
                Event::ScaleEpoch => self.on_scale_epoch(),
                Event::PrefillUp(inst) => self.on_prefill_up(inst),
                Event::DecodeUp(inst) => self.on_decode_up(inst),
                Event::Fault(i) => self.on_fault(i),
                Event::Heartbeat => self.on_heartbeat(),
                Event::DecodeRecover(rec) => self.on_decode_recover(rec),
                Event::PrefillRecover(rec) => self.on_prefill_recover(rec),
            }
        }
        self.report()
    }

    fn on_arrival(&mut self, idx: usize) {
        // context-cache lookup (prefix reuse) before routing: the P2P
        // architecture lets ANY instance use the shared cache.
        let prompt = self.requests[idx].spec.prompt.clone();
        let prompt_tokens = self.requests[idx].spec.prompt_tokens;
        let session = self.requests[idx].spec.session;
        self.win_prompt_tokens += prompt_tokens as u64;

        let mut reused = 0usize;
        let mut fetch_us = 0.0;
        if let Some(cc) = self.context_cache.as_mut() {
            if !prompt.is_empty() {
                let hit = cc.lookup(&mut self.pool, &prompt);
                reused = hit.reused_tokens.min(prompt_tokens.saturating_sub(1));
                fetch_us = hit.fetch_us;
            } else {
                // length-only trace: model reuse via session turns (each
                // prior turn's prompt prefix is cached)
                let turn = self.requests[idx].spec.turn;
                if turn > 0 {
                    reused = (prompt_tokens * 3 / 4).min(prompt_tokens - 1);
                    let bytes = reused as u64 * self.cfg.model.kv_bytes_per_token();
                    let over_ub = cc.over_ub;
                    let got = self.pool.net.transfer_us(
                        if over_ub {
                            crate::netsim::Plane::Ub
                        } else {
                            crate::netsim::Plane::Vpc
                        },
                        crate::netsim::PathKind::NpuToCpu,
                        crate::netsim::OpKind::Read,
                        crate::netsim::Locality::InterNode,
                        bytes,
                    );
                    fetch_us = got;
                    cc.block_hits += (reused / cc.block_tokens) as u64;
                    cc.block_misses += 1;
                }
            }
        }

        let compute = prompt_tokens - reused;
        let decision = self.router.route(session, compute as u64);
        if !decision.cache_usable {
            // KV-centric reroute: the local cache is on the wrong node
            self.recomputed_tokens += reused as u64;
            reused = 0;
            fetch_us = 0.0;
        }
        // a degraded fabric stretches pool fetches (chaos LinkDegrade /
        // rack-loss cascades), at the worst multiplier on the pool plane;
        // a UB-riding fetch is additionally homed on the consuming
        // instance's sub-plane (scoped brown-outs)
        fetch_us = self.pool_fetch_cost(fetch_us, decision.instance);
        self.cache_fetch_us_total += fetch_us;
        self.peak_router_imbalance = self.peak_router_imbalance.max(self.router.imbalance());

        let st = &mut self.requests[idx];
        st.reused_tokens = reused;
        st.prefill_instance = Some(decision.instance);
        st.phase = RequestPhase::QueuedPrefill;
        let ct = st.compute_tokens();
        let pl = st.spec.prompt_tokens;
        self.prefills[decision.instance].enqueue(idx as u64, ct, pl);
        self.push(self.now + fetch_us, Event::PrefillKick(decision.instance));
    }

    fn kick_prefill(&mut self, inst: usize) {
        if self.pf_failed[inst] {
            return; // dark NPUs; the queue re-homes at detection/recovery
        }
        if self.inflight_batches[inst].is_some() {
            return; // busy; PrefillDone will re-kick
        }
        let Some(batch) = self.prefills[inst].form_batch(self.opts.prefill_tokens_per_npu) else {
            return;
        };
        let mut lat = batch_latency_us(
            &self.cfg.die,
            &self.cfg.model,
            &self.cfg.serving,
            &batch,
            self.cfg.serving.npus_per_prefill,
            self.eplb_imbalance,
        );
        // placement locality: a spread slot's dispatch/combine crosses
        // racks beyond the calibrated packed layout (tax == 1.0 under
        // `Packed`)
        lat *= self.pf_tax[inst];
        // §6.2.1 donor tax: an instance hosting offloaded decode attention
        // donates HBM bandwidth, so its own batches run slower by the
        // modeled retained-throughput factor
        if let Some(o) = &self.offload {
            if self.router.is_donor(inst) {
                let extra = lat * (1.0 / o.prefill_retained - 1.0);
                lat += extra;
                self.donor_tax_us += extra;
            }
        }
        // the batch's flows are homed on the slot's UB sub-plane: a scoped
        // brown-out there stretches it for the window. Applied (and its
        // exposure accounted) on the fully taxed latency, like the decode
        // step's spike/straggle path — it measures actual extra wall time.
        lat = self.ub_homed_cost(lat, self.resilience.map.prefill_node(inst));
        let busy = lat * self.cfg.serving.npus_per_prefill as f64;
        self.acc_prefill_busy_npu_us += busy;
        self.win_prefill_busy_npu_us += busy;
        for &rid in &batch.requests {
            let st = &mut self.requests[rid as usize];
            st.phase = RequestPhase::Prefilling;
            st.t_prefill_start = Some(self.now);
        }
        self.inflight_batches[inst] = Some(batch);
        self.prefills[inst].busy_until = self.now + lat;
        let epoch = self.pf_epoch[inst];
        self.push(self.now + lat, Event::PrefillDone(inst, epoch));
    }

    fn on_prefill_done(&mut self, inst: usize, epoch: u64) {
        if epoch != self.pf_epoch[inst] {
            // completion of a batch that a crash already discarded
            return;
        }
        if self.pf_failed[inst] {
            // the instance died mid-batch: the batch is lost, not done.
            // Its requests stay in `inflight_batches` until the failure
            // detector re-homes (or loses) them at the next heartbeat.
            return;
        }
        let Some(batch) = self.inflight_batches[inst].take() else {
            return;
        };
        // RDMA KV push out of this instance: degraded when any link
        // touching its home node is (rack-loss cascades scope this); the
        // push's striping is homed on the node's UB sub-plane, so a
        // scoped brown-out there stretches it too (worst-case max, the
        // DegradationMap convention)
        let pf_node = self.resilience.map.prefill_node(inst);
        let link_mult = self.links.node_multiplier(Plane::Rdma, pf_node, self.now);
        self.router.complete(inst, batch.compute_tokens as u64);
        // store the new KV blocks back to the context cache (async; cost
        // charged to the pool but does not extend the critical path)
        if let Some(cc) = self.context_cache.as_mut() {
            for &rid in &batch.requests {
                let prompt = self.requests[rid as usize].spec.prompt.clone();
                if !prompt.is_empty() {
                    cc.store(&mut self.pool, &prompt);
                }
            }
        }
        // chaos: record prompt-KV pool residency per request (write-behind,
        // off the critical path) — a later decode crash re-fetches from
        // here when the blocks survive, or re-prefills when they are gone
        if let Some(ns) = self.kv_ns {
            for &rid in &batch.requests {
                let bytes = self.requests[rid as usize].spec.prompt_tokens as u64
                    * self.cfg.model.kv_bytes_per_token();
                self.pool.put(ns, chaos_kv_key(rid), bytes);
            }
        }
        for &rid in &batch.requests {
            let st = &mut self.requests[rid as usize];
            if st.recovering {
                // KV rebuild after a decode crash: the tokens streamed
                // before the crash are durable, so no first token, no
                // TTFT sample, no token counting — the rebuilt KV just
                // transfers back to a live decode instance.
                st.recovering = false;
                st.phase = RequestPhase::Transferring;
                // the rebuilt KV covers prompt AND the already-generated
                // suffix — all of it moves to the new decode instance
                let kv_tokens = st.spec.prompt_tokens + st.generated;
                let cost = kv_transfer(&self.pool.net, &self.cfg.model, kv_tokens);
                let mult = self.ub_homed_multiplier(link_mult, pf_node, cost.rdma_us);
                let cost = TransferCost { rdma_us: cost.rdma_us * mult, ..cost };
                let done = self.transfers.begin(rid, self.now, &cost);
                self.push(done, Event::TransferDone(rid));
                continue;
            }
            // prefill emits the request's first output token
            st.t_first_token = Some(self.now);
            st.t_last_token = Some(self.now);
            st.generated = 1;
            self.ttft.record(st.ttft_us().unwrap());
            self.win_output_tokens += 1;
            if st.is_done() {
                st.phase = RequestPhase::Finished;
                st.t_finished = Some(self.now);
                self.finished += 1;
                self.drop_chaos_kv(rid);
                continue;
            }
            st.phase = RequestPhase::Transferring;
            let cost = kv_transfer(&self.pool.net, &self.cfg.model, st.spec.prompt_tokens);
            let mult = self.ub_homed_multiplier(link_mult, pf_node, cost.rdma_us);
            let cost = TransferCost { rdma_us: cost.rdma_us * mult, ..cost };
            let done = self.transfers.begin(rid, self.now, &cost);
            self.push(done, Event::TransferDone(rid));
        }
        // more work queued?
        self.push(self.now, Event::PrefillKick(inst));
    }

    /// Decode-side placement: pick the pool instance for a ready request.
    /// Zero-capacity instances (shrunk away by a resplit) and failed ones
    /// (chaos) are never picked; `None` means no live instance exists
    /// right now (every instance crashed — possible only mid-chaos).
    fn place_decode(&mut self) -> Option<usize> {
        match self.opts.placement {
            DecodePlacement::RoundRobin => {
                for _ in 0..self.decodes.len() {
                    let i = self.rr_next % self.decodes.len();
                    self.rr_next = self.rr_next.wrapping_add(1);
                    if self.decodes[i].max_concurrent > 0 && !self.decode_failed[i] {
                        return Some(i);
                    }
                }
                None
            }
            DecodePlacement::LeastLoaded => {
                let mut best = None;
                let mut best_score = f64::INFINITY;
                for (i, d) in self.decodes.iter().enumerate() {
                    if d.max_concurrent == 0 || self.decode_failed[i] {
                        continue;
                    }
                    let load = d.slots.len() + self.decode_queues[i].len();
                    let score = load as f64 / d.max_concurrent as f64;
                    if score < best_score {
                        best_score = score;
                        best = Some(i);
                    }
                }
                best
            }
        }
    }

    /// Plane memory-pool fetches ride on (the Fig 23 UB-vs-VPC choice).
    fn pool_plane(&self) -> Plane {
        if self.cfg.serving.cache_over_ub {
            Plane::Ub
        } else {
            Plane::Vpc
        }
    }

    /// Charge a compute-path cost (prefill batch, decode step) the
    /// brown-out window of its home UB sub-plane: the component's
    /// dispatch/combine flows re-stripe over the surviving planes while
    /// the window is open. The excess over the undegraded cost is
    /// accounted as that plane's degradation exposure. Bit-identical
    /// pass-through when no brown-out window is active.
    fn ub_homed_cost(&mut self, cost_us: f64, node: u16) -> f64 {
        let plane = self.resilience.map.ub_plane(node);
        let pm = self.links.ub_plane_multiplier(plane, self.now);
        if pm > 1.0 {
            self.plane_exposure_us[plane] += cost_us * (pm - 1.0);
            cost_us * pm
        } else {
            cost_us
        }
    }

    /// Combine a flow's already-computed link multiplier with the
    /// brown-out window of its home UB sub-plane — worst-case `max`, the
    /// [`DegradationMap`] convention — charging only the *excess* the
    /// plane window adds (over `cost_us`) to that plane's exposure.
    fn ub_homed_multiplier(&mut self, other: f64, node: u16, cost_us: f64) -> f64 {
        let plane = self.resilience.map.ub_plane(node);
        let pm = self.links.ub_plane_multiplier(plane, self.now);
        if pm > other {
            self.plane_exposure_us[plane] += cost_us * (pm - other);
            pm
        } else {
            other
        }
    }

    /// Pool-fetch cost under the current fabric state: the pool plane's
    /// worst scoped/global multiplier, plus — when the fetch rides UB —
    /// the brown-out window of the consuming prefill slot's home
    /// sub-plane.
    fn pool_fetch_cost(&mut self, fetch_us: f64, inst: usize) -> f64 {
        let other = self.links.plane_multiplier(self.pool_plane(), self.now);
        if !self.cfg.serving.cache_over_ub {
            return fetch_us * other;
        }
        let node = self.resilience.map.prefill_node(inst);
        fetch_us * self.ub_homed_multiplier(other, node, fetch_us)
    }

    /// Drop a terminal request's chaos-KV residency entry: its prompt KV no
    /// longer needs crash recovery, and dead entries would otherwise
    /// pressure the pool's LRU against live context-cache blocks.
    fn drop_chaos_kv(&mut self, rid: u64) {
        if let Some(ns) = self.kv_ns {
            self.pool.delete(ns, chaos_kv_key(rid));
        }
    }

    /// Queue to park work on when no live decode instance exists: a failed
    /// instance (its replacement recovery is — or will be — scheduled, and
    /// its recovery drains the queue). `place_decode() == None` implies at
    /// least one instance is failed, because the decode-pool floor keeps
    /// capacity on some instance otherwise.
    fn park_decode_target(&self) -> usize {
        (0..self.decodes.len()).find(|&i| self.decode_failed[i]).unwrap_or(0)
    }

    fn on_transfer_done(&mut self, rid: u64) {
        self.transfers.poll(self.now);
        let inst = match self.place_decode() {
            Some(i) => i,
            None if self.recovery_enabled => {
                // every live-capacity instance is down but replacements are
                // coming: park on a failed instance; recovery drains it
                self.park_decode_target()
            }
            None => {
                // recovery disabled and the whole pool is dead
                self.lose_request(rid);
                return;
            }
        };
        let st = &mut self.requests[rid as usize];
        st.phase = RequestPhase::QueuedDecode;
        let tier = st.spec.slo_tier.min(self.tier_batch_per_npu.len() - 1);
        self.decode_queues[inst].push_tier(rid, tier);
        if !self.decode_failed[inst] && !self.decode_step_pending[inst] {
            self.decode_step_pending[inst] = true;
            self.push(self.now, Event::DecodeStep(inst));
        }
    }

    fn on_decode_step(&mut self, inst: usize) {
        if self.decode_failed[inst] {
            // the instance went dark: drop this (sole) outstanding step
            // chain; detection re-homes its work, recovery restarts steps.
            self.decode_step_pending[inst] = false;
            return;
        }
        // admit waiting requests into free slots: continuous batching with a
        // per-tier slot quota of `batch_for_slo(tier) x npus` (Table 5's
        // SLO-adaptive cap, applied per tier so a saturated loose tier can
        // never crowd a tight tier out of its quota, and vice versa)
        let npus = self.decodes[inst].npus;
        let free = self.decodes[inst].free_slots();
        let caps: Vec<usize> = self.tier_batch_per_npu.iter().map(|b| b * npus).collect();
        let mut occ = vec![0usize; caps.len()];
        for s in &self.decodes[inst].slots {
            occ[s.slo_tier.min(caps.len() - 1)] += 1;
        }
        let admitted = self.decode_queues[inst].admit_where(free, |tier| {
            if occ[tier] < caps[tier] {
                occ[tier] += 1;
                true
            } else {
                false
            }
        });
        for (rid, tier) in admitted {
            let st = &mut self.requests[rid as usize];
            debug_assert!(
                st.phase == RequestPhase::QueuedDecode,
                "request {rid} admitted twice into the decode pool"
            );
            st.phase = RequestPhase::Decoding;
            let remaining = st.spec.output_tokens.saturating_sub(st.generated).max(1);
            self.decodes[inst].admit_tiered(
                rid,
                st.spec.prompt_tokens + st.generated,
                remaining,
                tier,
            );
        }
        if self.decodes[inst].slots.is_empty() {
            self.decode_step_pending[inst] = false;
            return;
        }
        let model = self.decodes[inst].step_model(
            &self.cfg.die,
            &self.cfg.model,
            &self.cfg.serving,
            // per-instance imbalance: a resplit-shrunk instance has a lower
            // EP degree, packs experts multiple-per-rank, and pays for it
            self.decode_eplb[inst],
        );
        // §6.2.1 offload: the FA core's offloaded share runs concurrently
        // on donor prefill NPUs, shrinking the step (reusing the layer
        // breakdown the step model just computed). Never slower than the
        // all-local step: at a point where the remote share + UB sync
        // would dominate, the local share simply is the critical path.
        let mut step_us = model.step_us;
        if let Some(o) = &self.offload {
            let point =
                self.decodes[inst].decode_point(&self.cfg.serving, self.decode_eplb[inst]);
            let off_layer =
                offload::offloaded_layer_us(&self.cfg.model, &point, &model.layer, o.frac);
            let off_step = off_layer * self.cfg.model.n_layers as f64 + STEP_OVERHEAD_US;
            step_us = off_step.min(step_us);
        }
        // placement locality: a spread instance's dispatch/combine crosses
        // racks beyond the calibrated packed layout and pays the planner's
        // marginal tax (exactly 1.0 under `Packed`)
        let step_us = step_us * self.dec_tax[inst];
        // post-recall TPOT degradation window (donor-failure recalls): the
        // decode side re-stages the FA working set it pulled back. The
        // spike's accounted cost includes any concurrent straggler factor
        // — it measures the actual extra wall time the recall inflicted.
        let spike = self.recall_spike.multiplier(self.now);
        // a straggling instance (chaos) runs every step slower
        let straggle = self.straggle[inst].multiplier(self.now);
        self.recall_spike_us += step_us * straggle * (spike - 1.0);
        let step_us = step_us * spike * straggle;
        // the instance's dispatch/combine flows are homed on its node's UB
        // sub-plane: a scoped brown-out re-stripes them over the surviving
        // planes for the window (1.0 when no brown-out is active)
        let step_us = self.ub_homed_cost(step_us, self.resilience.map.decode_node(inst));
        self.acc_decode_busy_npu_us += step_us * self.decodes[inst].npus as f64;
        let step_end = self.now + step_us;
        let emits = self.decodes[inst].step(&self.cfg.serving);
        for e in emits {
            let st = &mut self.requests[e.request as usize];
            let last = st.t_last_token.unwrap_or(self.now);
            let per_tok = (step_end - last) / e.tokens as f64;
            for _ in 0..e.tokens {
                self.tpot.record(per_tok);
            }
            st.generated += e.tokens;
            self.win_output_tokens += e.tokens as u64;
            st.t_last_token = Some(step_end);
            if e.finished {
                st.phase = RequestPhase::Finished;
                st.t_finished = Some(step_end);
                self.finished += 1;
                self.drop_chaos_kv(e.request);
            }
        }
        self.push(step_end, Event::DecodeStep(inst));
    }

    // --- elastic PDC -------------------------------------------------------

    /// Fold elapsed virtual time into the per-role NPU-second integrals.
    /// Must be called before any change to the active split.
    fn integrate_npu_time(&mut self) {
        let dt = self.now - self.last_npu_t;
        if dt > 0.0 {
            // failed components count to neither pool from the instant of
            // the crash: their NPUs are dark until a replacement warm-loads
            // (pf_failed covers the crash-to-detection window, before the
            // router's failed mask catches up)
            let pf = (0..self.prefills.len())
                .filter(|&i| self.router.is_active(i) && !self.pf_failed[i])
                .count()
                * self.cfg.serving.npus_per_prefill;
            let dc: usize = self
                .decodes
                .iter()
                .enumerate()
                .filter(|&(i, _)| !self.decode_failed[i])
                .map(|(_, d)| d.npus)
                .sum();
            self.acc_prefill_npu_us += pf as f64 * dt;
            self.acc_decode_npu_us += dc as f64 * dt;
        }
        self.last_npu_t = self.now;
    }

    /// Re-spread the decode pool's NPUs across its instances after a move.
    /// When the pool shrinks below one NPU per instance, NPUs go to the
    /// instances holding the most slots (then deepest queue, then lowest
    /// index — deterministic), so compute is never credited to an empty
    /// instance while a loaded one sits at zero.
    fn redistribute_decode(&mut self, new_total: usize) {
        let batch0 = self.tier_batch_per_npu[0];
        let n = self.decodes.len();
        let sizes = split_even(new_total, n.min(new_total.max(1)));
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| {
            (
                std::cmp::Reverse(self.decodes[i].slots.len()),
                std::cmp::Reverse(self.decode_queues[i].len()),
                i,
            )
        });
        for (rank, &i) in order.iter().enumerate() {
            let npus = sizes.get(rank).copied().unwrap_or(0);
            self.decodes[i].resize(npus, batch0);
        }
        // EPLB follows the new per-instance EP degrees (satellite: elastic
        // moves pay the real post-resize imbalance in step_model)
        for i in 0..self.decodes.len() {
            let npus = self.decodes[i].npus;
            let imb = self.eplb_for_npus(npus);
            self.decode_eplb[i] = imb;
        }
        // rescue queued work stranded on a zero-capacity (or failed)
        // instance
        let best = (0..self.decodes.len())
            .filter(|&i| !self.decode_failed[i])
            .max_by_key(|&i| self.decodes[i].max_concurrent)
            .unwrap_or(0);
        for i in 0..self.decodes.len() {
            if self.decodes[i].max_concurrent == 0
                && i != best
                && !self.decode_queues[i].is_empty()
            {
                for (rid, tier) in self.decode_queues[i].admit_where(usize::MAX, |_| true) {
                    self.decode_queues[best].push_tier(rid, tier);
                }
            }
        }
        // grown capacity may unblock queued admissions
        for i in 0..self.decodes.len() {
            if !self.decode_failed[i]
                && !self.decode_step_pending[i]
                && (!self.decode_queues[i].is_empty() || !self.decodes[i].slots.is_empty())
            {
                self.decode_step_pending[i] = true;
                self.push(self.now, Event::DecodeStep(i));
            }
        }
    }

    fn decode_total_npus(&self) -> usize {
        self.decodes.iter().map(|d| d.npus).sum()
    }

    /// Memoized per-size instance imbalance (resplits revisit sizes).
    fn eplb_for_npus(&mut self, npus: usize) -> f64 {
        if let Some(&v) = self.eplb_cache.get(&npus) {
            return v;
        }
        let v = instance_eplb(
            &self.expert_hist,
            npus,
            self.cfg.serving.decode_redundant_experts,
        );
        self.eplb_cache.insert(npus, v);
        v
    }

    fn on_scale_epoch(&mut self) {
        let Some(ctl) = self.autoscaler.clone() else {
            return;
        };
        // live pressure signals
        let queue_tokens: u64 = (0..self.prefills.len())
            .filter(|&i| self.router.is_active(i))
            .map(|i| self.router.queued_tokens[i])
            .sum();
        let (slots, caps) = self
            .decodes
            .iter()
            .fold((0usize, 0usize), |(s, c), d| (s + d.slots.len(), c + d.max_concurrent));
        let stats = WorkloadStats {
            prompt_tokens: self.win_prompt_tokens,
            output_tokens: self.win_output_tokens,
            prefill_queue_tokens: queue_tokens as f64,
            decode_occupancy: if caps == 0 { 0.0 } else { slots as f64 / caps as f64 },
            window_us: self.scale_interval_us,
        };
        self.win_prompt_tokens = 0;
        self.win_output_tokens = 0;

        // §6.2.1 signals: the decode pool's operating point plus the
        // prefill idle headroom measured over this window (assigned minus
        // busy NPU-µs). Busy is credited at batch start, so a batch that
        // spills past the window edge would zero this window's idle AND
        // inflate the next window's: the excess over assigned time is
        // carried into the next window instead, conserving busy time
        // across windows so idle is never overestimated either side.
        self.integrate_npu_time();
        let window_assigned =
            (self.acc_prefill_npu_us - self.win_prefill_assigned_mark).max(0.0);
        let busy_in_window = self.win_prefill_busy_npu_us.min(window_assigned);
        let idle_npus = (window_assigned - busy_in_window) / self.scale_interval_us.max(1.0);
        self.win_prefill_busy_npu_us -= busy_in_window; // spill carries over
        self.win_prefill_assigned_mark = self.acc_prefill_npu_us;

        let sig = self.offload_signals(idle_npus);

        match ctl.recommend_action(
            &self.cfg.die,
            &self.cfg.model,
            &self.cfg.serving,
            &stats,
            &sig,
            self.target_prefill_npus,
            self.offload_enabled,
        ) {
            Some(ElasticAction::Resplit(plan)) => self.enact(&plan),
            Some(ElasticAction::Offload { frac, donors }) => self.engage_offload(frac, donors),
            Some(ElasticAction::Recall { reason }) => self.recall_offload(reason),
            None => {}
        }
        if self.finished + self.lost < self.requests.len() {
            let t = self.now + self.scale_interval_us;
            self.push(t, Event::ScaleEpoch);
        }
    }

    /// §6.2.1 signals at `now`: the decode pool's aggregate operating
    /// point (slot-weighted mean KV, total slots over pool NPUs,
    /// NPU-weighted per-instance EPLB) plus the prefill-side facts. The
    /// single source both the controller's decision and the enactment's
    /// donor-tax pricing read — they can never model different points.
    fn offload_signals(&self, prefill_idle_npus: f64) -> OffloadSignals {
        let total_slots: usize = self.decodes.iter().map(|d| d.slots.len()).sum();
        let kv_sum: usize =
            self.decodes.iter().flat_map(|d| d.slots.iter()).map(|s| s.kv_len).sum();
        let dec_npus = self.decode_total_npus();
        let eplb = if dec_npus == 0 {
            1.0
        } else {
            self.decodes
                .iter()
                .enumerate()
                .map(|(i, d)| self.decode_eplb[i] * d.npus as f64)
                .sum::<f64>()
                / dec_npus as f64
        };
        OffloadSignals {
            decode_mean_kv: if total_slots == 0 { 0 } else { kv_sum / total_slots },
            decode_batch_per_npu: total_slots.div_ceil(dec_npus.max(1)),
            decode_npus: dec_npus,
            prefill_npus: self.router.active_instances() * self.cfg.serving.npus_per_prefill,
            prefill_idle_npus,
            eplb_imbalance: eplb,
            offload_active: self.offload.as_ref().map(|o| o.frac),
        }
    }

    /// Engage §6.2.1 attention offloading: pick the most idle eligible
    /// prefill instances as donors and mark them in the router. Engagement
    /// is instantaneous — no weights move, and the FA core reads its KV
    /// over UB — so the only ongoing cost is the donors' bandwidth tax.
    /// Skipped (the controller retries next epoch) when the full donor set
    /// the controller's feasibility model assumed cannot be formed — e.g.
    /// a crashed-but-undetected slot shrank the candidate pool — or when
    /// it would consume every active instance.
    fn engage_offload(&mut self, frac: f64, donors_wanted: usize) {
        debug_assert!(self.offload.is_none(), "double offload engagement");
        debug_assert!(frac > 0.0 && frac <= 1.0, "offload frac out of [0,1]: {frac}");
        let mut cands: Vec<usize> = (0..self.prefills.len())
            .filter(|&i| {
                self.router.state(i) == InstanceState::Active
                    && !self.pf_pending_up[i]
                    && !self.pf_draining[i]
                    && !self.pf_failed[i]
            })
            .collect();
        // most idle first: emptiest queue, earliest free, lowest id
        cands.sort_by(|&a, &b| {
            self.router.queued_tokens[a]
                .cmp(&self.router.queued_tokens[b])
                .then(self.prefills[a].busy_until.total_cmp(&self.prefills[b].busy_until))
                .then(a.cmp(&b))
        });
        // domain-aware donor selection: with spreading on and the
        // candidate pool spanning ≥ 2 racks, pick donors round-robin
        // across racks (engaging a second donor if the controller asked
        // for one) so no single rack loss can fell the whole offloaded
        // core; the independent policy takes the most idle verbatim
        let wanted = self.resilience.donor_count(&cands, donors_wanted);
        let cands = self.resilience.pick_donors(&cands, wanted);
        if cands.is_empty()
            || cands.len() < donors_wanted
            || cands.len() >= self.router.active_instances()
        {
            return;
        }
        // donors' modeled retained throughput at the engagement-time
        // operating point — the exact point the controller decided from
        let sig = self.offload_signals(0.0);
        let point = Autoscaler::offload_point(&self.cfg.serving, &sig);
        let om = offload::model_offload(&self.cfg.die, &self.cfg.model, &point, frac);
        for &d in &cands {
            self.router.set_donor(d, true);
        }
        self.offload_events.push(OffloadEvent {
            t_us: self.now,
            kind: OffloadEventKind::Engage {
                frac,
                donors: cands.clone(),
                prefill_retained: om.prefill_retained,
            },
        });
        self.offload = Some(ActiveOffload {
            frac,
            donors: cands,
            prefill_retained: om.prefill_retained,
            engaged_us: self.now,
        });
    }

    /// Recall an active offload: donors return to plain prefill service.
    /// A donor-failure recall is forced — the decode side pulls the FA
    /// core back locally and pays the transient TPOT degradation window
    /// ([`RECALL_SPIKE_FACTOR`] for [`RECALL_SPIKE_US`]) rather than
    /// stalling; graceful recalls (pressure resolved, resplit preempting)
    /// cost nothing.
    fn recall_offload(&mut self, reason: RecallReason) {
        let share = match reason {
            RecallReason::DonorFailure | RecallReason::DomainIncident => 1.0,
            _ => 0.0,
        };
        self.recall_offload_scaled(reason, share);
    }

    /// Recall with an explicit lost-donor share: the forced-recall TPOT
    /// degradation window scales with the fraction of the offloaded FA
    /// core that actually died — re-staging 1/k of the working set costs
    /// 1/k of the window. `lost_share == 0` is a graceful (free) recall;
    /// the independent (non-domain-aware) policy always passes 1.0, the
    /// full PR-3 window. This is why domain-spread donors matter: a rack
    /// loss fells at most one of a spread set, while a co-located set
    /// dies wholesale.
    fn recall_offload_scaled(&mut self, reason: RecallReason, lost_share: f64) {
        let Some(o) = self.offload.take() else {
            return;
        };
        self.offload_active_us += self.now - o.engaged_us;
        for &d in &o.donors {
            // a failed donor already lost its donor state; this is a no-op
            // for it and restores the healthy donors to plain Active
            self.router.set_donor(d, false);
        }
        if lost_share > 0.0 {
            self.recall_spike = self.recall_spike.extend(
                self.now,
                RECALL_SPIKE_FACTOR,
                RECALL_SPIKE_US * lost_share.min(1.0),
            );
        }
        self.offload_events
            .push(OffloadEvent { t_us: self.now, kind: OffloadEventKind::Recall { reason } });
    }

    /// Enact a recommended split: move NPU groups between roles, modeling
    /// the role-switch latency (the group is offline in between).
    fn enact(&mut self, plan: &SplitPlan) {
        // Moving NPU groups while bandwidth is borrowed would invalidate
        // the donor set — return it first. Defense in depth: the
        // controller never recommends a resplit while an offload is
        // active, but enact() must hold the invariant on its own.
        if self.offload.is_some() {
            self.recall_offload(RecallReason::Preempted);
        }
        let quantum = self.cfg.serving.npus_per_prefill;
        let total = self.cfg.serving.total_npus();
        let cur = self.target_prefill_npus;
        if plan.prefill_npus > cur {
            // decode → prefill: NPUs leave the decode pool now, come up as
            // prefill instances after the role switch. Clamp the move to
            // the usable slot count BEFORE taking NPUs from decode, so a
            // partial enactment can never strand NPUs between roles.
            let usable_slots = (0..self.prefills.len())
                .filter(|&i| {
                    !self.router.is_active(i)
                        && !self.pf_pending_up[i]
                        && !self.pf_draining[i]
                        && !self.pf_failed[i]
                })
                .count();
            let avail = self.decode_total_npus().saturating_sub(quantum); // keep decode alive
            let k = ((plan.prefill_npus - cur) / quantum)
                .min(avail / quantum)
                .min(usable_slots);
            if k == 0 {
                return;
            }
            self.integrate_npu_time();
            let new_decode = self.decode_total_npus() - k * quantum;
            self.redistribute_decode(new_decode);
            let mut started = 0usize;
            for idx in 0..self.prefills.len() {
                if started == k {
                    break;
                }
                if !self.router.is_active(idx)
                    && !self.pf_pending_up[idx]
                    && !self.pf_draining[idx]
                    && !self.pf_failed[idx]
                {
                    self.pf_pending_up[idx] = true;
                    let t = self.now + self.switch_latency_us;
                    self.push(t, Event::PrefillUp(idx));
                    started += 1;
                }
            }
            debug_assert_eq!(started, k, "usable prefill slots vanished mid-enactment");
            self.target_prefill_npus = cur + started * quantum;
            self.resplits.push(ResplitEvent {
                t_us: self.now,
                from: Role::Decode,
                to: Role::Prefill,
                npus: started * quantum,
                prefill_npus_after: self.target_prefill_npus,
                // post-move split once every in-flight switch lands (the
                // instantaneous decode reading would under-count quanta
                // still mid drain from earlier moves)
                decode_npus_after: total - self.target_prefill_npus,
            });
        } else if plan.prefill_npus < cur {
            // prefill → decode: drain instances now (queues reassigned, any
            // inflight batch completes), NPUs join decode after the switch
            let k = (cur - plan.prefill_npus) / quantum;
            let active = self.router.active_instances();
            let k = k.min(active.saturating_sub(1)); // keep prefill alive
            if k == 0 {
                return;
            }
            self.integrate_npu_time();
            let mut drained = 0usize;
            for idx in (0..self.prefills.len()).rev() {
                if drained == k {
                    break;
                }
                // never drain a crashed-but-undetected slot: its NPUs are
                // dead and must not be converted into decode capacity
                if self.router.is_active(idx) && !self.pf_failed[idx] {
                    self.drain_prefill(idx);
                    drained += 1;
                }
            }
            self.target_prefill_npus = cur - drained * quantum;
            self.resplits.push(ResplitEvent {
                t_us: self.now,
                from: Role::Prefill,
                to: Role::Decode,
                npus: drained * quantum,
                prefill_npus_after: self.target_prefill_npus,
                decode_npus_after: total - self.target_prefill_npus,
            });
        }
    }

    /// Stop routing to a prefill instance, hand its queue to the remaining
    /// active instances, and schedule its NPUs to join the decode pool once
    /// any inflight batch and the role switch complete.
    fn drain_prefill(&mut self, idx: usize) {
        self.router.set_active(idx, false);
        self.pf_draining[idx] = true;
        let queued = std::mem::take(&mut self.prefills[idx].queue);
        for (rid, ct, pl) in queued {
            self.router.complete(idx, ct as u64);
            let session = self.requests[rid as usize].spec.session;
            // reassignment keeps the already-fetched prefix reuse (the KV
            // blocks live in the shared pool, P2P property §4.1)
            let d = self.router.route(session, ct as u64);
            self.requests[rid as usize].prefill_instance = Some(d.instance);
            self.prefills[d.instance].enqueue(rid, ct, pl);
            self.push(self.now, Event::PrefillKick(d.instance));
        }
        let free_at = self.prefills[idx].busy_until.max(self.now);
        let t = free_at + self.switch_latency_us;
        self.push(t, Event::DecodeUp(idx));
    }

    fn on_prefill_up(&mut self, idx: usize) {
        self.integrate_npu_time();
        self.pf_pending_up[idx] = false;
        self.router.set_active(idx, true);
        self.prefills[idx].busy_until = self.now;
        // a fresh instance may be the first routable one in a while
        // (chaos): rescue anything parked on dead slots
        self.resweep_stranded_prefill();
    }

    fn on_decode_up(&mut self, idx: usize) {
        self.integrate_npu_time();
        self.pf_draining[idx] = false;
        // a backfill loan whose replacement already arrived mid-switch
        // bounces straight back to prefill (paying the reverse switch)
        // without ever joining the decode pool
        if let Some(pos) = self.backfill_loans.iter().position(|l| l.slot == idx && l.returning) {
            self.backfill_loans.remove(pos);
            self.return_backfill_group(idx);
            return;
        }
        let new_total = self.decode_total_npus() + self.cfg.serving.npus_per_prefill;
        self.redistribute_decode(new_total);
    }

    // --- chaos: fault injection + recovery orchestration -------------------

    /// Injected fault `i` of the plan takes hardware effect. Crash classes
    /// stay invisible to the coordinator until the next heartbeat epoch;
    /// transient degradations apply immediately and self-expire. Raw target
    /// indices are retargeted deterministically onto a live, eligible
    /// component so every planned fault lands whenever at all possible.
    fn on_fault(&mut self, i: usize) {
        let Some(ev) = self.opts.faults.as_ref().and_then(|f| f.plan.events.get(i).copied())
        else {
            return;
        };
        match ev.kind {
            FaultKind::DecodeCrash { instance } => {
                let eligible: Vec<usize> = (0..self.decodes.len())
                    .filter(|&d| !self.decode_failed[d] && self.decodes[d].npus > 0)
                    .collect();
                let Some(&inst) = eligible.get(instance % eligible.len().max(1)) else {
                    return; // nothing left to crash
                };
                self.integrate_npu_time();
                self.decode_failed[inst] = true;
                let domain = Some(self.resilience.map.decode_rack(inst));
                self.fault_records.push(FaultRecord {
                    t_us: self.now,
                    kind: FaultKind::DecodeCrash { instance: inst },
                    detected_us: self.now, // provisional; set at detection
                    recovered_us: None,
                    requests_rehomed: 0,
                    requests_lost: 0,
                    kv_refetched: 0,
                    reprefilled: 0,
                    domain,
                });
                self.undetected.push(self.fault_records.len() - 1);
            }
            FaultKind::PrefillCrash { instance } => {
                let eligible: Vec<usize> = (0..self.prefills.len())
                    .filter(|&p| {
                        self.router.is_active(p)
                            && !self.pf_failed[p]
                            && !self.pf_draining[p]
                            && !self.pf_pending_up[p]
                    })
                    .collect();
                let Some(&idx) = eligible.get(instance % eligible.len().max(1)) else {
                    return;
                };
                self.integrate_npu_time();
                self.pf_failed[idx] = true;
                let domain = Some(self.resilience.map.prefill_rack(idx));
                self.fault_records.push(FaultRecord {
                    t_us: self.now,
                    kind: FaultKind::PrefillCrash { instance: idx },
                    detected_us: self.now,
                    recovered_us: None,
                    requests_rehomed: 0,
                    requests_lost: 0,
                    kv_refetched: 0,
                    reprefilled: 0,
                    domain,
                });
                self.undetected.push(self.fault_records.len() - 1);
            }
            FaultKind::PoolServerFail { server } => {
                let sid = server % self.pool.servers.len().max(1);
                // DRAM contents are gone; EVS-persisted blocks keep serving
                // from the SSD tier (§4.4.1) — no orchestration needed
                self.pool.fail_server(sid);
                let domain = Some(self.resilience.map.pool_rack(sid));
                self.fault_records.push(FaultRecord {
                    t_us: self.now,
                    kind: FaultKind::PoolServerFail { server: sid },
                    detected_us: self.now,
                    recovered_us: Some(self.now),
                    requests_rehomed: 0,
                    requests_lost: 0,
                    kv_refetched: 0,
                    reprefilled: 0,
                    domain,
                });
            }
            FaultKind::LinkDegrade { factor, duration_us } => {
                self.links.degrade_global(self.now, factor, duration_us);
                self.push_window_record(ev.kind, duration_us);
            }
            FaultKind::PlaneBrownout { plane, factor, duration_us } => {
                // scoped window: only flows homed on the lost sub-plane
                // degrade (a single-plane fabric degenerates to the legacy
                // whole-fabric window inside `brownout`)
                self.links.brownout(plane, UB_PLANES, self.now, factor, duration_us);
                self.push_window_record(ev.kind, duration_us);
            }
            FaultKind::Straggler { instance, factor, duration_us } => {
                let eligible: Vec<usize> = (0..self.decodes.len())
                    .filter(|&d| !self.decode_failed[d] && self.decodes[d].npus > 0)
                    .collect();
                let Some(&inst) = eligible.get(instance % eligible.len().max(1)) else {
                    return;
                };
                self.straggle[inst] = self.straggle[inst].extend(self.now, factor, duration_us);
                let domain = Some(self.resilience.map.decode_rack(inst));
                self.fault_records.push(FaultRecord {
                    t_us: self.now,
                    kind: FaultKind::Straggler { instance: inst, factor, duration_us },
                    detected_us: self.now,
                    recovered_us: Some(self.now + duration_us),
                    requests_rehomed: 0,
                    requests_lost: 0,
                    kv_refetched: 0,
                    reprefilled: 0,
                    domain,
                });
            }
            FaultKind::RackLoss { rack, factor, duration_us } => {
                self.on_rack_loss(rack, factor, duration_us);
            }
        }
    }

    /// Expand a correlated rack/PSU loss against the failure-domain map:
    /// every member prefill slot and decode instance crashes *now* (one
    /// member record each, all sharing the injection timestamp and domain
    /// — the incident's blast radius), member pool servers fail, and
    /// every fabric link touching the rack's nodes degrades for the
    /// power-restoration window. Detection and recovery then ride the
    /// ordinary per-component machinery, so the coordinator notices the
    /// whole incident at one heartbeat.
    fn on_rack_loss(&mut self, rack: usize, factor: f64, duration_us: Micros) {
        self.integrate_npu_time();
        let map = self.resilience.map.clone();
        for idx in map.prefill_members(rack) {
            if idx < self.prefills.len()
                && self.router.is_active(idx)
                && !self.pf_failed[idx]
                && !self.pf_draining[idx]
                && !self.pf_pending_up[idx]
            {
                self.pf_failed[idx] = true;
                self.fault_records.push(FaultRecord {
                    t_us: self.now,
                    kind: FaultKind::PrefillCrash { instance: idx },
                    detected_us: self.now,
                    recovered_us: None,
                    requests_rehomed: 0,
                    requests_lost: 0,
                    kv_refetched: 0,
                    reprefilled: 0,
                    domain: Some(rack),
                });
                self.undetected.push(self.fault_records.len() - 1);
            }
        }
        for d in map.decode_members(rack) {
            if d < self.decodes.len() && !self.decode_failed[d] && self.decodes[d].npus > 0 {
                self.decode_failed[d] = true;
                self.fault_records.push(FaultRecord {
                    t_us: self.now,
                    kind: FaultKind::DecodeCrash { instance: d },
                    detected_us: self.now,
                    recovered_us: None,
                    requests_rehomed: 0,
                    requests_lost: 0,
                    kv_refetched: 0,
                    reprefilled: 0,
                    domain: Some(rack),
                });
                self.undetected.push(self.fault_records.len() - 1);
            }
        }
        for s in map.pool_members(rack) {
            if s < self.pool.servers.len() {
                self.pool.fail_server(s);
                self.fault_records.push(FaultRecord {
                    t_us: self.now,
                    kind: FaultKind::PoolServerFail { server: s },
                    detected_us: self.now,
                    recovered_us: Some(self.now),
                    requests_rehomed: 0,
                    requests_lost: 0,
                    kv_refetched: 0,
                    reprefilled: 0,
                    domain: Some(rack),
                });
            }
        }
        // cascade: the rack's fabric ports flap while power is restored —
        // every UB/RDMA link touching its nodes runs degraded
        for node in map.rack_nodes(rack) {
            for plane in [Plane::Ub, Plane::Rdma] {
                self.links.degrade(LinkKey::node(plane, node), self.now, factor, duration_us);
            }
        }
    }

    /// Record a self-expiring degradation-window fault (`LinkDegrade` /
    /// `PlaneBrownout`): nothing strands, nothing re-homes — the window
    /// counts as recovered the instant it expires.
    fn push_window_record(&mut self, kind: FaultKind, duration_us: Micros) {
        self.fault_records.push(FaultRecord {
            t_us: self.now,
            kind,
            detected_us: self.now,
            recovered_us: Some(self.now + duration_us),
            requests_rehomed: 0,
            requests_lost: 0,
            kv_refetched: 0,
            reprefilled: 0,
            domain: None,
        });
    }

    /// Failure-detection epoch: newly-dead components are noticed, their
    /// stranded work re-dispatched (or declared lost when recovery is
    /// disabled), and replacement NPU groups scheduled at the warm
    /// model-load latency.
    fn on_heartbeat(&mut self) {
        let pending = std::mem::take(&mut self.undetected);
        // §6.2.1 × domains: donors lost this sweep force ONE recall before
        // the re-homing loop below — overlapped with it in the same epoch,
        // never serial per-donor recalls — with the TPOT spike window
        // scaled to the share of the offloaded FA core that actually died
        // (domain-spread donors lose a fraction; co-located donors lose it
        // all). A domain-wide incident (≥ 2 same-rack crashes in the
        // sweep) is tagged with its own recall reason when the mass-recall
        // policy is on.
        let (lost_donors, total_donors) = match &self.offload {
            Some(o) => {
                let lost = pending
                    .iter()
                    .filter(|&&r| {
                        matches!(self.fault_records[r].kind,
                            FaultKind::PrefillCrash { instance } if o.donors.contains(&instance))
                    })
                    .count();
                (lost, o.donors.len())
            }
            None => (0, 0),
        };
        if lost_donors > 0 {
            let mass = self.resilience.policy.mass_recall && self.domain_incident_in(&pending);
            let reason = if mass {
                RecallReason::DomainIncident
            } else {
                RecallReason::DonorFailure
            };
            // share-scaling of the spike window is part of the domain-aware
            // recall model; the independent baseline pays the full PR-3
            // window regardless of how many donors actually died
            let share = if self.resilience.policy.mass_recall {
                lost_donors as f64 / total_donors as f64
            } else {
                1.0
            };
            self.recall_offload_scaled(reason, share);
        }
        for rec in pending {
            self.fault_records[rec].detected_us = self.now;
            match self.fault_records[rec].kind {
                FaultKind::DecodeCrash { instance } => self.detect_decode_crash(instance, rec),
                FaultKind::PrefillCrash { instance } => self.detect_prefill_crash(instance, rec),
                _ => {}
            }
        }
        if !self.recovery_enabled {
            self.sweep_failed_queues();
        }
        if self.finished + self.lost < self.requests.len() {
            let t = self.now + self.hb_us;
            self.push(t, Event::Heartbeat);
        }
    }

    /// Whether ≥ 2 crashes detected in this heartbeat sweep share a
    /// failure domain — the signature of a correlated (rack-level)
    /// incident rather than coincident independent faults.
    fn domain_incident_in(&self, pending: &[usize]) -> bool {
        let mut doms: Vec<usize> =
            pending.iter().filter_map(|&r| self.fault_records[r].domain).collect();
        doms.sort_unstable();
        doms.windows(2).any(|w| w[0] == w[1])
    }

    /// A decode-instance crash is detected. In-flight slots lost their HBM
    /// KV state; queued requests lost nothing but their home. With recovery
    /// on, queued work re-homes across the live pool, slot requests take
    /// the KV re-fetch or re-prefill path, and a replacement group starts
    /// its warm model load. With recovery off, everything on the instance
    /// is lost and its NPUs never come back.
    fn detect_decode_crash(&mut self, inst: usize, rec: usize) {
        let slots: Vec<Slot> = std::mem::take(&mut self.decodes[inst].slots);
        let queued = self.decode_queues[inst].admit_where(usize::MAX, |_| true);
        if self.recovery_enabled {
            for s in slots {
                self.rehome_decode_slot(s, rec);
            }
            for (rid, tier) in queued {
                match self.place_decode() {
                    Some(target) => {
                        // actually moved — counted as re-dispatch work
                        self.fault_records[rec].requests_rehomed += 1;
                        self.decode_queues[target].push_tier(rid, tier);
                        if !self.decode_step_pending[target] {
                            self.decode_step_pending[target] = true;
                            self.push(self.now, Event::DecodeStep(target));
                        }
                    }
                    // the whole pool is down: park here until recovery
                    // (not a re-home — the request never moved)
                    None => self.decode_queues[inst].push_tier(rid, tier),
                }
            }
            let t = self.now + self.recovery_latency_us;
            self.push(t, Event::DecodeRecover(rec));
            // domain-aware backfill: borrow a prefill NPU group into the
            // decode pool for the replacement window instead of serving
            // the whole outage on the survivors
            if self.resilience.policy.backfill {
                self.try_backfill(rec);
            }
        } else {
            for s in slots {
                if self.lose_request(s.request) {
                    self.fault_records[rec].requests_lost += 1;
                }
            }
            for (rid, _) in queued {
                if self.lose_request(rid) {
                    self.fault_records[rec].requests_lost += 1;
                }
            }
        }
    }

    /// Backfill a crashed decode instance by draining the least-loaded
    /// pure-Active prefill group into the decode pool now — it joins after
    /// the Table 2 warm role-switch, bridging the (longer) domain
    /// replacement window — and logging the move as a backfill
    /// [`ResplitEvent`]. The loan is returned when fault `rec`'s
    /// replacement warm-loads. Skipped when no pure instance can be
    /// spared: ≥ 1 routable prefill instance must remain and donors are
    /// never drained (that would force an offload recall — worse than the
    /// trough the backfill bridges).
    fn try_backfill(&mut self, rec: usize) {
        if self.router.active_instances() <= 1 {
            return;
        }
        let cand = (0..self.prefills.len())
            .filter(|&i| {
                self.router.state(i) == InstanceState::Active
                    && !self.pf_failed[i]
                    && !self.pf_draining[i]
                    && !self.pf_pending_up[i]
            })
            .min_by_key(|&i| (self.router.queued_tokens[i], i));
        let Some(idx) = cand else {
            return;
        };
        self.integrate_npu_time();
        let quantum = self.cfg.serving.npus_per_prefill;
        self.drain_prefill(idx);
        self.backfill_loans.push(BackfillLoan { slot: idx, fault: rec, returning: false });
        self.target_prefill_npus = self.target_prefill_npus.saturating_sub(quantum);
        let total = self.cfg.serving.total_npus();
        self.resplits.push(ResplitEvent {
            t_us: self.now,
            from: Role::Prefill,
            to: Role::Decode,
            npus: quantum,
            prefill_npus_after: self.target_prefill_npus,
            decode_npus_after: total - self.target_prefill_npus,
        });
    }

    /// Send a returned backfill group back to its prefill slot: offline
    /// for the role switch, then `PrefillUp` reactivates the slot.
    fn return_backfill_group(&mut self, idx: usize) {
        let quantum = self.cfg.serving.npus_per_prefill;
        self.pf_pending_up[idx] = true;
        let t = self.now + self.switch_latency_us;
        self.push(t, Event::PrefillUp(idx));
        self.target_prefill_npus += quantum;
        let total = self.cfg.serving.total_npus();
        self.resplits.push(ResplitEvent {
            t_us: self.now,
            from: Role::Decode,
            to: Role::Prefill,
            npus: quantum,
            prefill_npus_after: self.target_prefill_npus,
            decode_npus_after: total - self.target_prefill_npus,
        });
    }

    /// Re-home one in-flight decode slot after its instance crashed. The
    /// tokens already streamed to the user are durable; what died with the
    /// instance is the KV state in HBM. If the prompt KV still lives in the
    /// memory pool (survived eviction and server crashes — §4.4.1), it is
    /// re-fetched and the request rejoins the decode queue after the fetch;
    /// otherwise everything the new instance needs (prompt + generated
    /// suffix) is recomputed through prefill.
    fn rehome_decode_slot(&mut self, slot: Slot, rec: usize) {
        let rid = slot.request;
        self.fault_records[rec].requests_rehomed += 1;
        self.requests[rid as usize].restarts += 1;
        let survived = match self.kv_ns {
            Some(ns) => {
                let over_ub = self.cfg.serving.cache_over_ub;
                let got = self.pool.get(ns, chaos_kv_key(rid), over_ub);
                got.hit.then_some(got.latency_us)
            }
            None => None,
        };
        match survived {
            Some(fetch_us) => {
                self.fault_records[rec].kv_refetched += 1;
                let st = &mut self.requests[rid as usize];
                st.phase = RequestPhase::Transferring;
                // recovery re-fetches take the plane-wide worst case, not
                // a home sub-plane window: the consuming instance is only
                // chosen at TransferDone, so the flow has no home yet
                let delay = fetch_us * self.links.plane_multiplier(self.pool_plane(), self.now);
                let t = self.now + delay;
                self.push(t, Event::TransferDone(rid));
            }
            None => {
                self.fault_records[rec].reprefilled += 1;
                let st = &mut self.requests[rid as usize];
                st.recovering = true;
                st.phase = RequestPhase::QueuedPrefill;
                // full recompute: the prompt KV is gone, and the generated
                // suffix must be rebuilt alongside it. Like every recovery
                // re-home, prefer non-donor instances — least-loaded alone
                // would land exactly on the (most idle) donors.
                let ct = st.spec.prompt_tokens + st.generated;
                let session = st.spec.session;
                let d = self.router.route_avoiding_donors(session, ct as u64);
                st.prefill_instance = Some(d.instance);
                self.prefills[d.instance].enqueue(rid, ct, ct);
                self.push(self.now, Event::PrefillKick(d.instance));
            }
        }
    }

    /// A prefill-instance crash is detected: mask it out of the router
    /// (forfeiting KV-centric homes), re-home its in-flight batch and queue
    /// (or lose them in baseline mode), and schedule the replacement.
    fn detect_prefill_crash(&mut self, idx: usize, rec: usize) {
        self.integrate_npu_time();
        // §6.2.1 fault interplay: crashed donors were handled by the
        // heartbeat's mass-recall pre-scan before this sweep started, so
        // the offload is already recalled by the time any donor's work is
        // re-homed here.
        debug_assert!(
            !self.offload.as_ref().is_some_and(|o| o.donors.contains(&idx)),
            "donor crash must be recalled before its detection sweep"
        );
        self.router.set_failed(idx, true);
        let inflight: Vec<u64> =
            self.inflight_batches[idx].take().map(|b| b.requests).unwrap_or_default();
        // the dead batch's pending PrefillDone must never complete a
        // replacement batch started after recovery
        self.pf_epoch[idx] += 1;
        let queued = std::mem::take(&mut self.prefills[idx].queue);
        if self.recovery_enabled {
            // in-flight batch requests and queued ones re-home the same
            // way: the batch ones just also lose their mid-compute work
            for rid in inflight.into_iter().chain(queued.into_iter().map(|(rid, _, _)| rid)) {
                self.fault_records[rec].requests_rehomed += 1;
                self.rehome_prefill_request(rid, idx);
            }
            let t = self.now + self.recovery_latency_us;
            self.push(t, Event::PrefillRecover(rec));
        } else {
            for rid in inflight {
                let ct = self.requests[rid as usize].compute_tokens();
                self.router.complete(idx, ct as u64);
                if self.lose_request(rid) {
                    self.fault_records[rec].requests_lost += 1;
                }
            }
            for (rid, ct, _) in queued {
                self.router.complete(idx, ct as u64);
                if self.lose_request(rid) {
                    self.fault_records[rec].requests_lost += 1;
                }
            }
        }
    }

    /// Terminal loss accounting: the request will never finish, and the
    /// conservation invariant becomes `finished + lost == admitted`.
    /// Returns whether the request was actually lost now (false if it
    /// already reached a terminal state — never double-counted).
    fn lose_request(&mut self, rid: u64) -> bool {
        let st = &mut self.requests[rid as usize];
        if matches!(st.phase, RequestPhase::Finished | RequestPhase::Lost) {
            return false;
        }
        st.phase = RequestPhase::Lost;
        st.t_lost = Some(self.now);
        self.lost += 1;
        self.drop_chaos_kv(rid);
        true
    }

    /// Recovery-disabled baseline: work that lands on (or was left on) dead
    /// components has no orchestrator to save it — declare it lost at each
    /// heartbeat so the run terminates with every request accounted.
    fn sweep_failed_queues(&mut self) {
        for idx in 0..self.prefills.len() {
            if !self.pf_failed[idx] {
                continue;
            }
            if let Some(batch) = self.inflight_batches[idx].take() {
                self.pf_epoch[idx] += 1;
                self.router.complete(idx, batch.compute_tokens as u64);
                for rid in batch.requests {
                    self.lose_request(rid);
                }
            }
            let queued = std::mem::take(&mut self.prefills[idx].queue);
            for (rid, ct, _) in queued {
                self.router.complete(idx, ct as u64);
                self.lose_request(rid);
            }
        }
        for i in 0..self.decodes.len() {
            if !self.decode_failed[i] {
                continue;
            }
            let slots: Vec<Slot> = std::mem::take(&mut self.decodes[i].slots);
            for s in slots {
                self.lose_request(s.request);
            }
            for (rid, _) in self.decode_queues[i].admit_where(usize::MAX, |_| true) {
                self.lose_request(rid);
            }
        }
    }

    /// Re-route one request out of prefill slot `from` (crashed or
    /// stranded): release its routing charge, pick a new home, and —
    /// exactly like `on_arrival` — forfeit the cached-prefix discount when
    /// the router says the reuse did not survive the move (a KV-centric
    /// home's local cache died with it; P2P reuse lives in the shared
    /// pool and always survives).
    fn rehome_prefill_request(&mut self, rid: u64, from: usize) {
        let st = &mut self.requests[rid as usize];
        if st.phase == RequestPhase::Prefilling {
            st.restarts += 1; // mid-compute work was lost with the batch
        }
        st.phase = RequestPhase::QueuedPrefill;
        let charge = if st.recovering {
            st.spec.prompt_tokens + st.generated
        } else {
            st.compute_tokens()
        };
        let session = st.spec.session;
        self.router.complete(from, charge as u64);
        // recovery prefers non-donor homes: a donor is already paying the
        // §6.2.1 bandwidth tax, so stranded work lands elsewhere when any
        // pure-Active instance exists
        let d = self.router.route_avoiding_donors(session, charge as u64);
        if !d.cache_usable && st.reused_tokens > 0 {
            self.recomputed_tokens += st.reused_tokens as u64;
            st.reused_tokens = 0;
        }
        let (ct, pl) = if st.recovering {
            let t = st.spec.prompt_tokens + st.generated;
            (t, t)
        } else {
            (st.compute_tokens(), st.spec.prompt_tokens)
        };
        st.prefill_instance = Some(d.instance);
        self.prefills[d.instance].enqueue(rid, ct, pl);
        self.push(self.now, Event::PrefillKick(d.instance));
    }

    /// Re-route queued work stranded on slots that are not currently
    /// routable (e.g. parked there while every prefill instance was down).
    fn resweep_stranded_prefill(&mut self) {
        if self.router.active_instances() == 0 {
            return;
        }
        for idx in 0..self.prefills.len() {
            if self.router.is_active(idx) || self.prefills[idx].queue.is_empty() {
                continue;
            }
            let queued = std::mem::take(&mut self.prefills[idx].queue);
            for (rid, _, _) in queued {
                self.rehome_prefill_request(rid, idx);
            }
        }
    }

    /// The replacement NPU group for a crashed decode instance is up
    /// (warm model load complete): the instance rejoins the pool and
    /// drains whatever parked on it meanwhile.
    fn on_decode_recover(&mut self, rec: usize) {
        let FaultKind::DecodeCrash { instance: inst } = self.fault_records[rec].kind else {
            return;
        };
        self.integrate_npu_time();
        self.fault_records[rec].recovered_us = Some(self.now);
        self.decode_failed[inst] = false;
        // the replacement obsoletes any backfill loan taken for this
        // fault: the borrowed NPU group goes home (or bounces back on
        // arrival if it is still mid role-switch; or the loan dissolves
        // when the autoscaler already repurposed the slot)
        if let Some(pos) = self.backfill_loans.iter().position(|l| l.fault == rec) {
            let loan = self.backfill_loans[pos];
            if self.pf_draining[loan.slot] {
                self.backfill_loans[pos].returning = true;
            } else {
                self.backfill_loans.remove(pos);
                if !self.router.is_active(loan.slot)
                    && !self.pf_pending_up[loan.slot]
                    && !self.pf_failed[loan.slot]
                {
                    let quantum = self.cfg.serving.npus_per_prefill;
                    let new_total = self.decode_total_npus().saturating_sub(quantum);
                    self.redistribute_decode(new_total);
                    self.return_backfill_group(loan.slot);
                }
            }
        }
        // a resplit may have shrunk the instance to zero while it was dark:
        // hand any parked queue to a live instance instead of stranding it
        if self.decodes[inst].max_concurrent == 0 && !self.decode_queues[inst].is_empty() {
            if let Some(target) = self.place_decode() {
                for (rid, tier) in self.decode_queues[inst].admit_where(usize::MAX, |_| true) {
                    self.decode_queues[target].push_tier(rid, tier);
                }
                if !self.decode_step_pending[target] {
                    self.decode_step_pending[target] = true;
                    self.push(self.now, Event::DecodeStep(target));
                }
            }
        }
        if !self.decode_step_pending[inst]
            && (!self.decode_queues[inst].is_empty() || !self.decodes[inst].slots.is_empty())
        {
            self.decode_step_pending[inst] = true;
            self.push(self.now, Event::DecodeStep(inst));
        }
    }

    /// The replacement NPU group for a crashed prefill slot is up: clear
    /// the failure masks, resume routing, and rescue anything stranded.
    fn on_prefill_recover(&mut self, rec: usize) {
        let FaultKind::PrefillCrash { instance: idx } = self.fault_records[rec].kind else {
            return;
        };
        self.integrate_npu_time();
        self.fault_records[rec].recovered_us = Some(self.now);
        self.pf_failed[idx] = false;
        self.router.set_failed(idx, false);
        self.prefills[idx].busy_until = self.now;
        self.resweep_stranded_prefill();
        self.push(self.now, Event::PrefillKick(idx));
    }

    // --- reporting ---------------------------------------------------------

    fn report(&mut self) -> ServingReport {
        self.integrate_npu_time();
        // close the books on a still-engaged offload (idempotent: the
        // engagement clock restarts at `now`)
        if let Some(o) = self.offload.as_mut() {
            self.offload_active_us += self.now - o.engaged_us;
            o.engaged_us = self.now;
        }
        let duration = self
            .requests
            .iter()
            .filter_map(|r| r.t_finished)
            .fold(0.0f64, f64::max)
            .max(self.now);
        let prompt_tokens: u64 =
            self.requests.iter().filter(|r| r.t_first_token.is_some()).map(|r| r.spec.prompt_tokens as u64).sum();
        let output_tokens: u64 = self.requests.iter().map(|r| r.generated as u64).sum();
        let goodput_tokens: u64 = self
            .requests
            .iter()
            .filter(|r| r.phase == RequestPhase::Finished)
            .map(|r| r.generated as u64)
            .sum();
        let tokens_lost: u64 = self
            .requests
            .iter()
            .filter(|r| r.phase == RequestPhase::Lost)
            .map(|r| r.undelivered_tokens())
            .sum();
        ServingReport {
            duration_us: duration,
            requests_completed: self.finished as u64,
            prompt_tokens,
            output_tokens,
            ttft_us: (&self.ttft).into(),
            tpot_us: (&self.tpot).into(),
            prefill_npus: self.cfg.serving.prefill_instances * self.cfg.serving.npus_per_prefill,
            decode_npus: self.cfg.serving.decode_npus,
            prefill_npu_seconds: self.acc_prefill_npu_us / 1e6,
            decode_npu_seconds: self.acc_decode_npu_us / 1e6,
            prefill_busy_npu_seconds: self.acc_prefill_busy_npu_us / 1e6,
            decode_busy_npu_seconds: self.acc_decode_busy_npu_us / 1e6,
            tier_attainment: self.tier_attainment(),
            resplits: self.resplits.clone(),
            offload_events: self.offload_events.clone(),
            offload_active_us: self.offload_active_us,
            donor_tax_us: self.donor_tax_us,
            recall_spike_us: self.recall_spike_us,
            faults: self.fault_records.clone(),
            requests_lost: self.lost as u64,
            tokens_lost,
            goodput_tokens,
            plane_exposure_us: self.plane_exposure_us.clone(),
            placement_objective: self.cfg.serving.placement,
            placement_score: self.placement.placement_score,
        }
    }

    /// Per-tier SLO attainment over finished requests.
    fn tier_attainment(&self) -> Vec<TierAttainment> {
        let n_tiers = self.cfg.serving.n_tiers();
        let mut out = Vec::with_capacity(n_tiers);
        for tier in 0..n_tiers {
            let slo = self.cfg.serving.slo_for_tier(tier);
            let mut requests = 0u64;
            let (mut ttft_ok, mut tpot_ok, mut both_ok) = (0u64, 0u64, 0u64);
            for r in &self.requests {
                if r.spec.slo_tier.min(n_tiers - 1) != tier || r.t_finished.is_none() {
                    continue;
                }
                requests += 1;
                let t_ok = r.ttft_us().is_some_and(|t| t <= slo.ttft_ms * 1000.0);
                let p_ok = if r.generated > 1 {
                    let span = r.t_finished.unwrap() - r.t_first_token.unwrap();
                    span / (r.generated - 1) as f64 <= slo.tpot_ms * 1000.0
                } else {
                    true
                };
                ttft_ok += u64::from(t_ok);
                tpot_ok += u64::from(p_ok);
                both_ok += u64::from(t_ok && p_ok);
            }
            let frac = |n: u64| if requests == 0 { 1.0 } else { n as f64 / requests as f64 };
            out.push(TierAttainment {
                tier,
                tpot_slo_ms: slo.tpot_ms,
                ttft_slo_ms: slo.ttft_ms,
                requests,
                ttft_attained: frac(ttft_ok),
                tpot_attained: frac(tpot_ok),
                attained: frac(both_ok),
            });
        }
        out
    }

    /// Context-cache hit rate observed during the run.
    pub fn cache_hit_rate(&self) -> f64 {
        self.context_cache.as_ref().map(|c| c.hit_rate()).unwrap_or(0.0)
    }

    /// Router queue imbalance at end of run.
    pub fn router_imbalance(&self) -> f64 {
        self.router.imbalance()
    }

    /// Measured EPLB residual imbalance used by the engine models.
    pub fn eplb_imbalance(&self) -> f64 {
        self.eplb_imbalance
    }

    /// The resplit log so far (also included in the final report).
    pub fn resplit_log(&self) -> &[ResplitEvent] {
        &self.resplits
    }

    /// The chaos fault log so far (also included in the final report).
    pub fn fault_log(&self) -> &[FaultRecord] {
        &self.fault_records
    }

    /// The §6.2.1 offload transition log so far (also in the report).
    pub fn offload_log(&self) -> &[OffloadEvent] {
        &self.offload_events
    }

    /// Currently engaged offload as `(frac, donor slots)`, if any.
    pub fn active_offload(&self) -> Option<(f64, &[usize])> {
        self.offload.as_ref().map(|o| (o.frac, o.donors.as_slice()))
    }

    /// Requests declared lost so far (recovery-disabled baseline).
    pub fn lost_requests(&self) -> usize {
        self.lost
    }

    /// The failure-domain layout this run is placed over (tests, tools).
    pub fn domain_map(&self) -> &FailureDomainMap {
        &self.resilience.map
    }

    /// The scored placement-layout report this run was planned with
    /// (tests, tools).
    pub fn placement_report(&self) -> &PlacementReport {
        &self.placement
    }

    /// Per-component placement locality taxes `(prefill slots, decode
    /// instances)` in effect — all exactly 1.0 under `Packed` (tests).
    pub fn placement_taxes(&self) -> (&[f64], &[f64]) {
        (&self.pf_tax, &self.dec_tax)
    }

    /// Backfill loans currently out, as `(prefill slot, fault record)`
    /// pairs (tests, tools).
    pub fn backfill_loans(&self) -> Vec<(usize, usize)> {
        self.backfill_loans.iter().map(|l| (l.slot, l.fault)).collect()
    }

    /// Per-decode-instance residual EPLB imbalance currently in effect
    /// (recomputed on every resplit resize — tests, tools).
    pub fn decode_eplb(&self) -> &[f64] {
        &self.decode_eplb
    }

    /// Read-only view of the decode-instance pool (tests, tools).
    pub fn decode_pool(&self) -> &[DecodeInstance] {
        &self.decodes
    }

    /// Current (instantaneous) NPU split as (prefill, decode); NPUs mid
    /// role-switch belong to neither side.
    pub fn current_split(&self) -> (usize, usize) {
        (
            self.router.active_instances() * self.cfg.serving.npus_per_prefill,
            self.decode_total_npus(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeploymentPreset;
    use crate::config::ServingConfig;
    use crate::workload::{generate, WorkloadSpec};

    fn small_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.serving = ServingConfig::preset(DeploymentPreset::Paper256);
        cfg
    }

    fn run_with(n: usize, opts: SimOptions) -> (ServingReport, ServeSim) {
        let cfg = small_cfg();
        let trace = generate(&WorkloadSpec::paper_default(opts.seed + 1), n);
        let mut sim = ServeSim::new(cfg, opts, trace);
        let report = sim.run();
        (report, sim)
    }

    #[test]
    fn completes_all_requests() {
        let (report, _) = run_with(200, SimOptions::default());
        assert_eq!(report.requests_completed, 200);
        assert!(report.output_tokens > 0);
        assert!(report.duration_us > 0.0);
    }

    #[test]
    fn every_request_monotone_lifecycle() {
        let (_, sim) = run_with(100, SimOptions::default());
        for r in &sim.requests {
            let first = r.t_first_token.expect("all requests got a first token");
            assert!(first >= r.spec.arrival_us);
            let done = r.t_finished.expect("all finished");
            assert!(done >= first);
            assert_eq!(r.generated, r.spec.output_tokens.max(1));
        }
    }

    #[test]
    fn tpot_respects_slo_roughly() {
        let (report, _) = run_with(300, SimOptions::default());
        // mean TPOT should be under ~1.5x the 50 ms SLO even under load
        assert!(
            report.tpot_us.mean < 75_000.0,
            "mean TPOT {:.1} ms",
            report.tpot_us.mean / 1000.0
        );
    }

    #[test]
    fn p2p_beats_kv_centric_on_balance() {
        let p2p = run_with(400, SimOptions { seed: 5, ..SimOptions::default() });
        let kvc = run_with(
            400,
            SimOptions {
                seed: 5,
                router: RouterKind::KvCentric { overload_factor: 3.0 },
                ..SimOptions::default()
            },
        );
        // KV-centric must not *beat* P2P on TTFT; typically it is worse
        assert!(
            kvc.0.ttft_us.p99 >= p2p.0.ttft_us.p99 * 0.9,
            "p2p p99 {:.0} kvc p99 {:.0}",
            p2p.0.ttft_us.p99,
            kvc.0.ttft_us.p99
        );
    }

    #[test]
    fn context_cache_reduces_prefill_work() {
        let mut with = small_cfg();
        with.serving.context_caching = true;
        let mut without = small_cfg();
        without.serving.context_caching = false;
        let trace = generate(&WorkloadSpec::paper_default(9), 300);
        let r_with = ServeSim::new(with, SimOptions::default(), trace.clone()).run();
        let r_without = ServeSim::new(without, SimOptions::default(), trace).run();
        // same completed tokens, faster (or equal) end-to-end with caching
        assert_eq!(r_with.requests_completed, r_without.requests_completed);
        assert!(
            r_with.ttft_us.mean <= r_without.ttft_us.mean * 1.02,
            "cache should not hurt TTFT: {} vs {}",
            r_with.ttft_us.mean,
            r_without.ttft_us.mean
        );
    }

    #[test]
    fn decode_pool_completes_and_spreads_load() {
        for placement in [DecodePlacement::LeastLoaded, DecodePlacement::RoundRobin] {
            let (report, sim) = run_with(
                200,
                SimOptions { decode_instances: 4, placement, ..SimOptions::default() },
            );
            assert_eq!(report.requests_completed, 200, "{placement:?}");
            // every pool instance saw traffic
            for (i, d) in sim.decodes.iter().enumerate() {
                assert!(d.tokens_emitted > 0, "{placement:?}: instance {i} idle");
            }
            // pool sizes partition the decode NPUs
            assert_eq!(sim.decode_total_npus(), sim.cfg.serving.decode_npus);
        }
    }

    #[test]
    fn decode_pool_matches_single_instance_totals() {
        let (single, _) = run_with(150, SimOptions { seed: 2, ..SimOptions::default() });
        let (pooled, _) = run_with(
            150,
            SimOptions { seed: 2, decode_instances: 2, ..SimOptions::default() },
        );
        assert_eq!(single.requests_completed, pooled.requests_completed);
        assert_eq!(single.output_tokens, pooled.output_tokens);
    }

    #[test]
    fn frozen_run_logs_no_resplits_and_integrates_npu_time() {
        let (report, _) = run_with(120, SimOptions::default());
        assert!(report.resplits.is_empty());
        let dur_s = report.duration_us / 1e6;
        let pf = report.prefill_npus as f64 * dur_s;
        let dc = report.decode_npus as f64 * dur_s;
        assert!((report.prefill_npu_seconds - pf).abs() / pf < 1e-6);
        assert!((report.decode_npu_seconds - dc).abs() / dc < 1e-6);
    }

    #[test]
    fn autoscaled_run_is_deterministic() {
        let opts = || SimOptions {
            seed: 11,
            autoscale: Some(AutoscaleOptions {
                interval_us: 5e5,
                switch_latency_us: 1e6,
                ..AutoscaleOptions::default()
            }),
            ..SimOptions::default()
        };
        let (a, _) = run_with(200, opts());
        let (b, _) = run_with(200, opts());
        assert_eq!(a.duration_us, b.duration_us);
        assert_eq!(a.output_tokens, b.output_tokens);
        assert_eq!(a.resplits.len(), b.resplits.len());
        assert_eq!(a.requests_completed, 200);
    }

    #[test]
    fn healthy_run_measures_busy_vs_assigned_npu_time() {
        let (report, _) = run_with(150, SimOptions::default());
        assert!(report.prefill_busy_npu_seconds > 0.0);
        assert!(report.decode_busy_npu_seconds > 0.0);
        // busy can never exceed assigned role time on a healthy run — the
        // gap is the idle headroom the offload controller borrows against
        assert!(
            report.prefill_busy_npu_seconds <= report.prefill_npu_seconds * 1.0001,
            "prefill busy {} vs assigned {}",
            report.prefill_busy_npu_seconds,
            report.prefill_npu_seconds
        );
        assert!(
            report.decode_busy_npu_seconds <= report.decode_npu_seconds * 1.0001,
            "decode busy {} vs assigned {}",
            report.decode_busy_npu_seconds,
            report.decode_npu_seconds
        );
        // no autoscaler → §6.2.1 offload can never engage
        assert!(report.offload_events.is_empty());
        assert_eq!(report.offload_active_us, 0.0);
        assert_eq!(report.donor_tax_us, 0.0);
        assert_eq!(report.recall_spike_us, 0.0);
    }

    #[test]
    fn offload_engage_and_recall_mechanics() {
        let cfg = small_cfg();
        let trace = generate(&WorkloadSpec::paper_default(1), 10);
        let opts =
            SimOptions { autoscale: Some(AutoscaleOptions::default()), ..SimOptions::default() };
        let mut sim = ServeSim::new(cfg, opts, trace);
        sim.engage_offload(0.3, 2);
        {
            let (frac, donors) = sim.active_offload().expect("offload engaged");
            assert_eq!(frac, 0.3);
            assert_eq!(donors.len(), 2);
        }
        assert_eq!(sim.offload_log().len(), 1);
        // graceful recall: donors return to Active, no spike window opens
        sim.recall_offload(RecallReason::PressureResolved);
        assert!(sim.active_offload().is_none());
        assert_eq!(sim.offload_log().len(), 2);
        assert!(!sim.recall_spike.is_active(sim.now + 1.0));
        assert_eq!(sim.recall_spike_us, 0.0);
        // re-engagement works, and a forced (donor-failure) recall opens
        // the transient TPOT degradation window
        sim.engage_offload(0.2, 1);
        sim.recall_offload(RecallReason::DonorFailure);
        assert!(sim.recall_spike.is_active(sim.now + RECALL_SPIKE_US / 2.0));
        // recalling with nothing active is a no-op
        sim.recall_offload(RecallReason::Preempted);
        assert_eq!(sim.offload_log().len(), 4);
    }

    #[test]
    fn offload_engagement_requires_a_pure_instance() {
        let mut cfg = small_cfg();
        cfg.serving.prefill_instances = 1; // a single prefill instance
        let trace = generate(&WorkloadSpec::paper_default(2), 10);
        let opts =
            SimOptions { autoscale: Some(AutoscaleOptions::default()), ..SimOptions::default() };
        let mut sim = ServeSim::new(cfg, opts, trace);
        // the sole active instance may not become a donor — the pool needs
        // at least one untaxed prefill instance
        sim.engage_offload(0.3, 1);
        assert!(sim.active_offload().is_none());
        assert!(sim.offload_log().is_empty());
    }

    #[test]
    fn switch_latency_is_model_cache_warm_load() {
        let us = default_switch_latency_us();
        // Table 2: ~5 s warm switch for the 671 GB model over the pool
        assert!(us > 1e6 && us < 2e7, "switch latency {us} µs");
    }

    // --- chaos -------------------------------------------------------------

    use crate::faults::{FaultEvent, FaultKind, FaultOptions, FaultPlan};

    fn chaos_opts(events: Vec<FaultEvent>, recovery: bool) -> SimOptions {
        SimOptions {
            seed: 3,
            decode_instances: 2,
            faults: Some(FaultOptions {
                plan: FaultPlan::new(events),
                heartbeat_us: 1e5,
                recovery,
                recovery_latency_us: 1e6,
            }),
            ..SimOptions::default()
        }
    }

    #[test]
    fn empty_fault_plan_matches_healthy_run() {
        // identical options apart from the chaos plumbing itself
        let healthy = run_with(
            150,
            SimOptions { seed: 3, decode_instances: 2, ..SimOptions::default() },
        );
        let chaos = run_with(150, chaos_opts(Vec::new(), true));
        // chaos plumbing with nothing scheduled must not perturb the sim —
        // bit-for-bit, not just on conserved counters
        assert_eq!(healthy.0.duration_us.to_bits(), chaos.0.duration_us.to_bits());
        assert_eq!(healthy.0.ttft_us.p99.to_bits(), chaos.0.ttft_us.p99.to_bits());
        assert_eq!(healthy.0.tpot_us.p99.to_bits(), chaos.0.tpot_us.p99.to_bits());
        assert_eq!(healthy.0.requests_completed, chaos.0.requests_completed);
        assert_eq!(healthy.0.output_tokens, chaos.0.output_tokens);
        assert!(chaos.0.faults.is_empty());
        assert_eq!(chaos.0.requests_lost, 0);
        assert_eq!(chaos.0.availability(), 1.0);
    }

    #[test]
    fn decode_crash_recovers_and_completes_all() {
        let ev = vec![FaultEvent {
            t_us: 2e6,
            kind: FaultKind::DecodeCrash { instance: 0 },
        }];
        let (report, sim) = run_with(300, chaos_opts(ev, true));
        assert_eq!(report.requests_completed, 300, "recovery must save every request");
        assert_eq!(report.requests_lost, 0);
        assert_eq!(report.availability(), 1.0);
        assert_eq!(report.faults.len(), 1);
        let rec = &report.faults[0];
        assert!(rec.detected_us >= rec.t_us);
        let recovered = rec.recovered_us.expect("replacement must come up");
        assert!(recovered > rec.detected_us);
        assert!(rec.requests_rehomed > 0, "a busy instance must strand work: {rec:?}");
        // only in-flight slots split into refetch/re-prefill; queued
        // re-homes need neither
        assert!(rec.kv_refetched + rec.reprefilled <= rec.requests_rehomed);
        assert!(report.mean_mttr_us().unwrap() >= 1e6);
        // every re-homed request still delivered its exact token count
        for r in &sim.requests {
            assert_eq!(r.generated, r.spec.output_tokens.max(1), "request {}", r.spec.id);
        }
    }

    #[test]
    fn recovery_disabled_baseline_loses_requests() {
        let ev = vec![FaultEvent {
            t_us: 2e6,
            kind: FaultKind::DecodeCrash { instance: 0 },
        }];
        let (with, _) = run_with(300, chaos_opts(ev.clone(), true));
        let (without, sim) = run_with(300, chaos_opts(ev, false));
        assert!(without.requests_lost > 0, "a dead instance with no recovery must lose work");
        assert_eq!(
            without.requests_completed + without.requests_lost,
            300,
            "every request accounted exactly once"
        );
        assert!(without.availability() < 1.0);
        assert!(without.tokens_lost > 0);
        assert!(
            with.goodput_tokens > without.goodput_tokens,
            "recovery must strictly beat the baseline on goodput: {} vs {}",
            with.goodput_tokens,
            without.goodput_tokens
        );
        // lost requests are explicitly stamped, never silently dropped
        for r in &sim.requests {
            match r.phase {
                RequestPhase::Finished => assert!(r.t_finished.is_some()),
                RequestPhase::Lost => assert!(r.t_lost.is_some()),
                other => panic!("request {} ended in {:?}", r.spec.id, other),
            }
        }
    }

    #[test]
    fn prefill_crash_rehomes_and_recovers() {
        let ev = vec![FaultEvent {
            t_us: 3e5,
            kind: FaultKind::PrefillCrash { instance: 2 },
        }];
        let (report, _) = run_with(300, chaos_opts(ev, true));
        assert_eq!(report.requests_completed, 300);
        assert_eq!(report.faults.len(), 1);
        assert!(report.faults[0].recovered_us.is_some());
    }

    #[test]
    fn pool_server_failure_is_transparent_to_serving() {
        let ev = vec![FaultEvent {
            t_us: 1e6,
            kind: FaultKind::PoolServerFail { server: 1 },
        }];
        let (report, _) = run_with(200, chaos_opts(ev, true));
        // persisted blocks survive on EVS; serving completes regardless
        assert_eq!(report.requests_completed, 200);
        assert_eq!(report.faults.len(), 1);
        assert_eq!(report.requests_lost, 0);
    }

    #[test]
    fn gray_failures_slow_but_complete() {
        let healthy = run_with(200, SimOptions { seed: 3, ..SimOptions::default() });
        let ev = vec![
            FaultEvent {
                t_us: 1e5,
                kind: FaultKind::Straggler { instance: 0, factor: 3.0, duration_us: 5e6 },
            },
            FaultEvent {
                t_us: 1e5,
                kind: FaultKind::LinkDegrade { factor: 4.0, duration_us: 5e6 },
            },
        ];
        let opts = SimOptions {
            faults: Some(FaultOptions {
                plan: FaultPlan::new(ev),
                heartbeat_us: 1e5,
                recovery: true,
                recovery_latency_us: 1e6,
            }),
            seed: 3,
            ..SimOptions::default()
        };
        let (report, _) = run_with(200, opts);
        assert_eq!(report.requests_completed, 200);
        assert_eq!(report.faults.len(), 2);
        assert_eq!(report.requests_lost, 0);
        assert!(
            report.duration_us >= healthy.0.duration_us,
            "degradation cannot speed the run up: {} vs {}",
            report.duration_us,
            healthy.0.duration_us
        );
    }

    #[test]
    fn plane_brownout_degrades_only_plane_homed_flows() {
        let healthy = run_with(200, SimOptions { seed: 3, ..SimOptions::default() });
        // the single decode instance homes at node 12 → UB sub-plane 5;
        // prefill slots home on planes {0, 1, 2, 3, 4, 6}
        let ev = vec![FaultEvent {
            t_us: 1e5,
            kind: FaultKind::PlaneBrownout { plane: 5, factor: 7.0 / 6.0, duration_us: 1e9 },
        }];
        let opts = SimOptions {
            faults: Some(FaultOptions {
                plan: FaultPlan::new(ev),
                heartbeat_us: 1e5,
                recovery: true,
                recovery_latency_us: 1e6,
            }),
            seed: 3,
            ..SimOptions::default()
        };
        let (report, sim) = run_with(200, opts);
        assert_eq!(report.requests_completed, 200);
        assert_eq!(sim.domain_map().ub_plane(sim.domain_map().decode_node(0)), 5);
        // only flows homed on the browned-out plane paid for it
        assert!(report.plane_exposure_us[5] > 0.0, "{:?}", report.plane_exposure_us);
        for (p, &e) in report.plane_exposure_us.iter().enumerate() {
            if p != 5 {
                assert_eq!(e, 0.0, "plane {p} hosts no decode flows and must be untouched");
            }
        }
        // the drag is real: every decode step inside the window ran slower
        assert!(report.duration_us > healthy.0.duration_us);
        assert_eq!(report.faults.len(), 1);
        assert_eq!(report.requests_lost, 0);
    }

    #[test]
    fn spread_placement_completes_and_reports_the_trade() {
        use crate::config::PlacementObjective;
        let mut cfg = small_cfg();
        cfg.serving.placement = PlacementObjective::SpreadRacks;
        let trace = generate(&WorkloadSpec::paper_default(4), 150);
        let opts = SimOptions { seed: 4, decode_instances: 4, ..SimOptions::default() };
        let mut sim = ServeSim::new(cfg, opts, trace);
        let report = sim.run();
        assert_eq!(report.requests_completed, 150);
        assert_eq!(report.placement_objective, PlacementObjective::SpreadRacks);
        assert!(report.placement_score > 0.0 && report.placement_score <= 1.0);
        // the locality cost is priced but marginal (≤ the full tax rate)
        let (pf_tax, dec_tax) = sim.placement_taxes();
        assert!(pf_tax.iter().chain(dec_tax).all(|&t| (1.0..1.05).contains(&t)));
        // the packed default prices no tax at all — bit-exact legacy path
        let (_, packed) = run_with(50, SimOptions::default());
        let (pf0, dec0) = packed.placement_taxes();
        assert!(pf0.iter().chain(dec0).all(|&t| t == 1.0));
        assert_eq!(packed.placement_report().locality_score, 1.0);
    }

    #[test]
    fn chaos_run_is_deterministic() {
        let ev = || {
            vec![
                FaultEvent { t_us: 1e6, kind: FaultKind::DecodeCrash { instance: 1 } },
                FaultEvent { t_us: 2e6, kind: FaultKind::PrefillCrash { instance: 0 } },
                FaultEvent { t_us: 3e6, kind: FaultKind::PoolServerFail { server: 0 } },
            ]
        };
        let (a, _) = run_with(250, chaos_opts(ev(), true));
        let (b, _) = run_with(250, chaos_opts(ev(), true));
        assert_eq!(a.duration_us.to_bits(), b.duration_us.to_bits());
        assert_eq!(a.output_tokens, b.output_tokens);
        assert_eq!(a.goodput_tokens, b.goodput_tokens);
        assert_eq!(a.faults.len(), b.faults.len());
        for (x, y) in a.faults.iter().zip(&b.faults) {
            assert_eq!(x.t_us.to_bits(), y.t_us.to_bits());
            assert_eq!(x.detected_us.to_bits(), y.detected_us.to_bits());
            assert_eq!(x.requests_rehomed, y.requests_rehomed);
        }
    }

    #[test]
    fn per_instance_eplb_tracks_pool_split() {
        // one full-size instance: the per-instance imbalance IS the global
        let (_, single) = run_with(50, SimOptions::default());
        assert_eq!(single.decode_eplb().len(), 1);
        assert!((single.decode_eplb()[0] - single.eplb_imbalance()).abs() < 1e-12);
        // split pool: each instance is sized at half the EP degree and its
        // imbalance is recomputed for that size, not the init-time global
        let (_, split) = run_with(
            50,
            SimOptions { decode_instances: 2, ..SimOptions::default() },
        );
        assert_eq!(split.decode_eplb().len(), 2);
        assert_eq!(split.decode_eplb()[0], split.decode_eplb()[1]);
        let mut ea = ExpertActivation::new(
            split.opts.seed ^ 0xE9,
            split.cfg.model.n_routed_experts,
            1.05,
        );
        let hist = ea.batch_histogram(8192, split.cfg.model.top_k);
        let expected = instance_eplb(
            &hist,
            split.cfg.serving.decode_npus / 2,
            split.cfg.serving.decode_redundant_experts,
        );
        assert_eq!(split.decode_eplb()[0], expected);
        for &v in split.decode_eplb() {
            assert!((1.0..=1.6).contains(&v), "imbalance out of range: {v}");
        }
    }

    #[test]
    fn instance_eplb_covers_both_packing_regimes() {
        let mut ea = ExpertActivation::new(0xE9, 256, 1.05);
        let hist = ea.batch_histogram(8192, 8);
        let full = instance_eplb(&hist, 160, 32); // 320 ranks: replica path
        let half = instance_eplb(&hist, 80, 32); // 160 ranks: LPT packing
        assert!((1.0..=1.6).contains(&full), "{full}");
        assert!((1.0..=1.6).contains(&half), "{half}");
        // a drained-away instance degrades to the neutral multiplier
        assert_eq!(instance_eplb(&hist, 0, 32), 1.0);
    }
}
