//! Request lifecycle state machine for the PDC pipeline.

use crate::workload::Request;
use crate::Micros;

pub type RequestId = u64;

/// Where a request currently is in the PDC pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestPhase {
    /// Waiting in a prefill instance's queue.
    QueuedPrefill,
    /// Being prefetched/prefilled.
    Prefilling,
    /// KV cache in flight over the RDMA plane.
    Transferring,
    /// Waiting for a decode slot.
    QueuedDecode,
    /// Generating tokens.
    Decoding,
    Finished,
    /// Dropped by a failure with recovery orchestration disabled (chaos
    /// baseline): the request will never finish, and is explicitly
    /// accounted as lost — conservation is `Finished + Lost = admitted`.
    Lost,
}

/// Full per-request tracking state.
#[derive(Debug, Clone)]
pub struct RequestState {
    pub spec: Request,
    pub phase: RequestPhase,
    /// Prefill instance handling this request.
    pub prefill_instance: Option<usize>,
    /// Tokens whose KV came from the context cache (skipped compute).
    pub reused_tokens: usize,
    pub t_prefill_start: Option<Micros>,
    pub t_first_token: Option<Micros>,
    pub t_finished: Option<Micros>,
    /// Virtual time the request was declared lost (chaos baseline only).
    pub t_lost: Option<Micros>,
    /// Output tokens produced so far.
    pub generated: usize,
    /// Virtual time the previous token was emitted (TPOT tracking).
    pub t_last_token: Option<Micros>,
    /// Set while the request is rebuilding KV state after a decode-instance
    /// crash (re-prefill path): prefill completion must then *not* emit a
    /// first token, record TTFT, or double-count — the tokens streamed
    /// before the crash are durable; only the KV is being recomputed.
    pub recovering: bool,
    /// How many times a fault forced this request to restart work.
    pub restarts: u32,
}

impl RequestState {
    pub fn new(spec: Request) -> Self {
        RequestState {
            spec,
            phase: RequestPhase::QueuedPrefill,
            prefill_instance: None,
            reused_tokens: 0,
            t_prefill_start: None,
            t_first_token: None,
            t_finished: None,
            t_lost: None,
            generated: 0,
            t_last_token: None,
            recovering: false,
            restarts: 0,
        }
    }

    /// Tokens the prefill engine must actually compute (after cache reuse).
    pub fn compute_tokens(&self) -> usize {
        self.spec.prompt_tokens.saturating_sub(self.reused_tokens).max(1)
    }

    /// TTFT in µs, if the first token has been produced.
    pub fn ttft_us(&self) -> Option<Micros> {
        self.t_first_token.map(|t| t - self.spec.arrival_us)
    }

    pub fn is_done(&self) -> bool {
        self.generated >= self.spec.output_tokens
    }

    /// Output tokens promised but not delivered (lost-token accounting;
    /// every request delivers at least one token when it completes).
    pub fn undelivered_tokens(&self) -> u64 {
        self.spec.output_tokens.max(1).saturating_sub(self.generated) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt: usize, output: usize) -> Request {
        Request {
            id: 1,
            arrival_us: 100.0,
            prompt_tokens: prompt,
            output_tokens: output,
            prompt: vec![],
            session: 0,
            turn: 0,
            slo_tier: 0,
            xpod_import_tokens: 0,
        }
    }

    #[test]
    fn compute_tokens_respects_reuse() {
        let mut st = RequestState::new(req(4096, 10));
        assert_eq!(st.compute_tokens(), 4096);
        st.reused_tokens = 1024;
        assert_eq!(st.compute_tokens(), 3072);
        st.reused_tokens = 5000; // over-reuse clamps to 1 (suffix token)
        assert_eq!(st.compute_tokens(), 1);
    }

    #[test]
    fn ttft_math() {
        let mut st = RequestState::new(req(16, 4));
        assert!(st.ttft_us().is_none());
        st.t_first_token = Some(600.0);
        assert_eq!(st.ttft_us(), Some(500.0));
    }

    #[test]
    fn done_condition() {
        let mut st = RequestState::new(req(16, 3));
        st.generated = 2;
        assert!(!st.is_done());
        st.generated = 3;
        assert!(st.is_done());
    }

    #[test]
    fn lost_requests_are_stamped_and_account_undelivered() {
        let mut st = RequestState::new(req(16, 5));
        st.generated = 2;
        st.phase = RequestPhase::Lost;
        st.t_lost = Some(900.0);
        assert!(!st.is_done());
        assert_eq!(st.undelivered_tokens(), 3);
        // a finished request has nothing undelivered
        st.generated = 5;
        assert_eq!(st.undelivered_tokens(), 0);
    }
}
