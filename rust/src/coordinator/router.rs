//! Request routing (paper §4.1): the peer-to-peer stateless scheduler vs
//! the KVCache-centric baseline.
//!
//! * **Peer-to-peer** (this paper): KV blocks live in the shared
//!   disaggregated pool, uniformly accessible over UB — so the router is
//!   *stateless* and free to pick the least-loaded prefill instance. Cache
//!   hits do not depend on placement.
//!
//! * **KVCache-centric** (Dynamo/Mooncake style): cached KV lives in a
//!   specific instance's local DRAM. The router must send a session back
//!   to its *home* instance to reuse cache; rerouting for load balance
//!   forfeits the cached prefix (recompute). This coupling is exactly the
//!   scheduling-complexity/load-balance tension §4.1 argues against.

use std::collections::BTreeMap;

/// Routing decision for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteDecision {
    pub instance: usize,
    /// Whether locally-held cache remains usable after this routing.
    pub cache_usable: bool,
}

/// Lifecycle state of one prefill instance slot. Replaces the old parallel
/// `active`/`failed` bool masks so the §6.2.1 offload-donor role does not
/// become a third ad-hoc mask.
///
/// * `Active` — serving prefill traffic.
/// * `Drained` — voluntarily out of the prefill role (elastic drain); its
///   NPUs are (or will be) decode capacity.
/// * `Failed` — masked out by the failure detector. Failure is an *overlay*:
///   the `drained` bit remembers the role state it covered, so recovery
///   restores exactly that state (a slot that was drained when it crashed
///   comes back drained, not routable).
/// * `Donor` — active *and* donating HBM bandwidth to offloaded decode
///   attention (§6.2.1): still admissible for prefill traffic, but paying
///   the donor tax on batch latency and deprioritized when recovery
///   re-homes stranded work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceState {
    Active,
    Drained,
    Failed { drained: bool },
    Donor,
}

/// Router behavior under comparison.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RouterKind {
    PeerToPeer,
    KvCentric {
        /// Queue-depth ratio (vs least-loaded) beyond which the KV-centric
        /// router abandons affinity and reroutes (losing the cache).
        overload_factor: f64,
    },
}

/// The router: tracks per-instance queued compute tokens.
#[derive(Debug)]
pub struct Router {
    pub kind: RouterKind,
    /// Outstanding queued tokens per prefill instance.
    pub queued_tokens: Vec<u64>,
    /// Per-slot lifecycle state (see [`InstanceState`]). The elastic
    /// autoscaler activates/drains slots as NPUs move between roles, marks
    /// donors while §6.2.1 attention offload is engaged, and the failure
    /// detector overlays `Failed` until recovery.
    state: Vec<InstanceState>,
    /// session → home instance (KV-centric affinity state; the P2P router
    /// keeps NO such state — that is the point).
    home: BTreeMap<u64, usize>,
    /// session → the instance that last prefilled it (SGLang-style
    /// cache-affinity hint for P2P serving). Unlike `home`, this is a
    /// *soft latency* hint, not a correctness dependency: the prefix KV
    /// lives in the shared pool either way, so a non-affine placement
    /// pays the UB pool fetch, never a recompute. Only
    /// [`Router::route_affinity`] reads or writes it — plain
    /// [`Router::route`] stays stateless, bit-for-bit.
    affinity: BTreeMap<u64, usize>,
}

impl Router {
    pub fn new(kind: RouterKind, n_instances: usize) -> Router {
        Router {
            kind,
            queued_tokens: vec![0; n_instances],
            state: vec![InstanceState::Active; n_instances],
            home: BTreeMap::new(),
            affinity: BTreeMap::new(),
        }
    }

    /// The slot's lifecycle state.
    pub fn state(&self, instance: usize) -> InstanceState {
        self.state[instance]
    }

    /// Mark an instance slot active (serving prefill) or draining/inactive.
    /// Draining a donor implicitly ends its donor role; toggling the role
    /// of a failed slot only updates the state recovery will restore.
    pub fn set_active(&mut self, instance: usize, on: bool) {
        self.state[instance] = match (self.state[instance], on) {
            (InstanceState::Failed { .. }, true) => InstanceState::Failed { drained: false },
            (InstanceState::Failed { .. }, false) => InstanceState::Failed { drained: true },
            (InstanceState::Donor, true) => InstanceState::Donor,
            (_, true) => InstanceState::Active,
            (_, false) => InstanceState::Drained,
        };
    }

    /// Mark an instance slot failed (failure detector) or recovered.
    /// Failed slots receive no traffic and — for the KV-centric baseline —
    /// forfeit every session home pointing at them, exactly like drained
    /// slots: the local cache died with the instance. A failed donor loses
    /// its donor role permanently (the sim recalls the offload); recovery
    /// brings it back as a plain `Active` slot.
    pub fn set_failed(&mut self, instance: usize, failed: bool) {
        self.state[instance] = match (self.state[instance], failed) {
            (InstanceState::Drained, true) => InstanceState::Failed { drained: true },
            (InstanceState::Failed { drained }, true) => InstanceState::Failed { drained },
            (_, true) => InstanceState::Failed { drained: false },
            (InstanceState::Failed { drained: true }, false) => InstanceState::Drained,
            (InstanceState::Failed { drained: false }, false) => InstanceState::Active,
            (other, false) => other,
        };
        if failed {
            // the failed instance's HBM-resident prefix blocks are gone, so
            // the soft affinity hints pointing at it are dead weight — drop
            // them now rather than letting them pin map growth. (Routing is
            // unchanged: `route_affinity` already treats a hint at an
            // inactive instance exactly like no hint.) The KV-centric
            // `home` map deliberately stays: a stale home is load-bearing
            // for the cache-forfeit accounting in `decide`.
            self.affinity.retain(|_, &mut inst| inst != instance);
        }
    }

    /// Mark an `Active` slot as an offload donor (§6.2.1), or return a
    /// donor to plain `Active`. Offload may never be hosted on a drained
    /// or failed slot — that is the point of unifying the masks.
    pub fn set_donor(&mut self, instance: usize, donor: bool) {
        if donor {
            assert!(
                self.state[instance] == InstanceState::Active,
                "offload donor must be an Active prefill instance, not {:?}",
                self.state[instance]
            );
            self.state[instance] = InstanceState::Donor;
        } else if self.state[instance] == InstanceState::Donor {
            self.state[instance] = InstanceState::Active;
        }
    }

    pub fn is_failed(&self, instance: usize) -> bool {
        matches!(self.state[instance], InstanceState::Failed { .. })
    }

    /// Currently donating bandwidth to offloaded decode attention.
    pub fn is_donor(&self, instance: usize) -> bool {
        self.state[instance] == InstanceState::Donor
    }

    /// Routable: serving the prefill role *and* not marked failed. Donors
    /// stay admissible for prefill traffic.
    pub fn is_active(&self, instance: usize) -> bool {
        matches!(self.state[instance], InstanceState::Active | InstanceState::Donor)
    }

    pub fn active_instances(&self) -> usize {
        (0..self.state.len()).filter(|&i| self.is_active(i)).count()
    }

    fn least_loaded_where(&self, keep: impl Fn(usize) -> bool) -> Option<usize> {
        self.queued_tokens
            .iter()
            .enumerate()
            .filter(|&(i, _)| self.is_active(i) && keep(i))
            .min_by_key(|&(_, &q)| q)
            .map(|(i, _)| i)
    }

    /// Route like [`Router::route`], restricted to active instances the
    /// predicate keeps; falls back to the unrestricted least-loaded choice
    /// when the predicate filters every routable instance out. The general
    /// form behind soft placement preferences — a preference must degrade
    /// gracefully rather than strand work. Returns `None` only when ZERO
    /// instances are routable at all (see [`Router::route`]).
    pub fn route_where(
        &mut self,
        session: u64,
        tokens: u64,
        keep: impl Fn(usize) -> bool,
    ) -> Option<RouteDecision> {
        match self.least_loaded_where(keep) {
            Some(pick) => {
                let decision = self.decide(session, tokens, pick);
                self.commit(session, tokens, &decision);
                Some(decision)
            }
            None => self.route(session, tokens),
        }
    }

    /// Route like [`Router::route`], but prefer instances that are NOT
    /// offload donors: the recovery orchestrator re-homes stranded work
    /// here, and a donor is already paying the §6.2.1 bandwidth tax — when
    /// any pure-Active instance exists, the stranded work goes there.
    /// Falls back to the plain least-loaded choice (donors included) when
    /// every routable instance is donating; `None` when nothing routes.
    pub fn route_avoiding_donors(&mut self, session: u64, tokens: u64) -> Option<RouteDecision> {
        match self.least_loaded_where(|i| !self.is_donor(i)) {
            Some(pick) => {
                let decision = self.decide(session, tokens, pick);
                self.commit(session, tokens, &decision);
                Some(decision)
            }
            None => self.route(session, tokens),
        }
    }

    /// Cache-affinity routing for P2P serving (SGLang-style): prefer the
    /// instance that last prefilled this session — its prefix KV blocks
    /// are still resident in local HBM, so a hit there skips even the UB
    /// pool fetch — unless that instance is gone or overloaded past
    /// `overload_factor` (the same queue-ratio test the KV-centric
    /// baseline uses), in which case the request falls back to the
    /// least-loaded instance and pays the pool fetch for whatever prefix
    /// is still cached. Returns the decision plus whether the affine
    /// (local-HBM) placement was taken, or `None` when zero instances are
    /// routable — no tokens are charged and no affinity is recorded in
    /// that case; the caller holds the request queued. `cache_usable` is
    /// always true: the shared pool survives any placement — that is the
    /// §4.1 difference from the KV-centric `home` map.
    pub fn route_affinity(
        &mut self,
        session: u64,
        tokens: u64,
        overload_factor: f64,
    ) -> Option<(RouteDecision, bool)> {
        let least = self.least_loaded_where(|_| true)?;
        let (pick, local) = match self.affinity.get(&session) {
            Some(&aff) if self.is_active(aff) => {
                let aff_q = self.queued_tokens[aff] as f64;
                let least_q = self.queued_tokens[least] as f64;
                if aff_q <= (least_q + tokens as f64) * overload_factor {
                    (aff, true)
                } else {
                    (least, false)
                }
            }
            _ => (least, false),
        };
        self.affinity.insert(session, pick);
        self.queued_tokens[pick] += tokens;
        Some((RouteDecision { instance: pick, cache_usable: true }, local))
    }

    /// Route a request; caller charges `tokens` of prefill work. Returns
    /// `None` when zero instances are routable (mass failure / full drain):
    /// nothing is charged and the caller must hold the request queued until
    /// capacity returns — the old behavior of silently charging slot 0
    /// routed real work onto a `Failed` instance.
    pub fn route(&mut self, session: u64, tokens: u64) -> Option<RouteDecision> {
        let least = self.least_loaded_where(|_| true)?;
        let decision = self.decide(session, tokens, least);
        self.commit(session, tokens, &decision);
        Some(decision)
    }

    /// The routing decision given the preferred least-loaded pick.
    fn decide(&self, session: u64, tokens: u64, least: usize) -> RouteDecision {
        match self.kind {
            RouterKind::PeerToPeer => {
                // stateless least-loaded; cache is in the shared pool, so
                // it survives any placement.
                RouteDecision { instance: least, cache_usable: true }
            }
            RouterKind::KvCentric { overload_factor } => {
                match self.home.get(&session) {
                    // a drained or failed home instance lost its local
                    // cache with it
                    Some(&home) if !self.is_active(home) => {
                        RouteDecision { instance: least, cache_usable: false }
                    }
                    Some(&home) => {
                        let home_q = self.queued_tokens[home] as f64;
                        let least_q = self.queued_tokens[least] as f64;
                        if home_q <= (least_q + tokens as f64) * overload_factor {
                            RouteDecision { instance: home, cache_usable: true }
                        } else {
                            // overload: reroute and lose the local cache
                            RouteDecision { instance: least, cache_usable: false }
                        }
                    }
                    None => RouteDecision { instance: least, cache_usable: true },
                }
            }
        }
    }

    /// Record a decision: update KV-centric affinity and charge the queue.
    fn commit(&mut self, session: u64, tokens: u64, decision: &RouteDecision) {
        if let RouterKind::KvCentric { .. } = self.kind {
            self.home.insert(session, decision.instance);
        }
        self.queued_tokens[decision.instance] += tokens;
    }

    /// Work completed on an instance.
    pub fn complete(&mut self, instance: usize, tokens: u64) {
        self.queued_tokens[instance] = self.queued_tokens[instance].saturating_sub(tokens);
    }

    /// Drop every per-session routing hint for a terminal session: the
    /// P2P affinity hint AND the KV-centric home. A session that will
    /// never arrive again can influence no future decision, so eviction is
    /// behavior-free — it only bounds both maps by the number of sessions
    /// that still have requests in flight or in the future.
    pub fn evict_session(&mut self, session: u64) {
        self.affinity.remove(&session);
        self.home.remove(&session);
    }

    /// Sessions currently tracked across the affinity + home maps
    /// (observability for the bounded-growth regression tests).
    pub fn tracked_sessions(&self) -> usize {
        self.affinity.len() + self.home.len()
    }

    /// Load imbalance across *active* instances: max/mean queued tokens.
    pub fn imbalance(&self) -> f64 {
        let active: Vec<u64> = self
            .queued_tokens
            .iter()
            .enumerate()
            .filter(|&(i, _)| self.is_active(i))
            .map(|(_, &q)| q)
            .collect();
        let total: u64 = active.iter().sum();
        if total == 0 || active.is_empty() {
            return 1.0;
        }
        let mean = total as f64 / active.len() as f64;
        let max = *active.iter().max().unwrap() as f64;
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_balances_load() {
        let mut r = Router::new(RouterKind::PeerToPeer, 4);
        for s in 0..100u64 {
            r.route(s % 5, 1000).unwrap(); // 5 hot sessions
        }
        assert!(r.imbalance() < 1.1, "imbalance {}", r.imbalance());
    }

    #[test]
    fn kv_centric_hotspots_on_hot_sessions() {
        let mut r = Router::new(RouterKind::KvCentric { overload_factor: 8.0 }, 4);
        for s in 0..100u64 {
            r.route(s % 2, 1000).unwrap(); // 2 hot sessions pin 2 instances
        }
        assert!(r.imbalance() > 1.5, "imbalance {}", r.imbalance());
    }

    #[test]
    fn kv_centric_keeps_affinity_when_feasible() {
        let mut r = Router::new(RouterKind::KvCentric { overload_factor: 4.0 }, 2);
        let first = r.route(7, 100).unwrap();
        assert!(first.cache_usable);
        let again = r.route(7, 100).unwrap();
        assert_eq!(again.instance, first.instance);
        assert!(again.cache_usable);
    }

    #[test]
    fn kv_centric_reroute_loses_cache() {
        let mut r = Router::new(RouterKind::KvCentric { overload_factor: 1.0 }, 2);
        let first = r.route(7, 1_000_000).unwrap();
        // other instance empty → overload triggers reroute
        let again = r.route(7, 100).unwrap();
        assert_ne!(again.instance, first.instance);
        assert!(!again.cache_usable, "reroute must forfeit local cache");
    }

    #[test]
    fn p2p_cache_always_usable() {
        let mut r = Router::new(RouterKind::PeerToPeer, 2);
        r.route(1, 1_000_000).unwrap();
        let d = r.route(1, 100).unwrap();
        assert!(d.cache_usable);
    }

    #[test]
    fn inactive_instances_receive_no_traffic() {
        let mut r = Router::new(RouterKind::PeerToPeer, 3);
        r.set_active(1, false);
        for s in 0..30u64 {
            let d = r.route(s, 100).unwrap();
            assert_ne!(d.instance, 1, "drained instance must not be routed to");
        }
        assert_eq!(r.queued_tokens[1], 0);
        assert_eq!(r.active_instances(), 2);
        // reactivation brings it back as the least-loaded target
        r.set_active(1, true);
        assert_eq!(r.route(99, 1).unwrap().instance, 1);
    }

    #[test]
    fn kv_centric_drained_home_forfeits_cache() {
        let mut r = Router::new(RouterKind::KvCentric { overload_factor: 100.0 }, 2);
        let first = r.route(7, 100).unwrap();
        r.set_active(first.instance, false);
        let again = r.route(7, 100).unwrap();
        assert_ne!(again.instance, first.instance);
        assert!(!again.cache_usable, "cache on a drained instance is gone");
    }

    #[test]
    fn failed_instances_receive_no_traffic_until_recovered() {
        let mut r = Router::new(RouterKind::PeerToPeer, 3);
        r.set_failed(1, true);
        assert!(r.is_failed(1));
        assert!(!r.is_active(1), "failed slot must not be routable");
        assert_eq!(r.active_instances(), 2);
        for s in 0..30u64 {
            let d = r.route(s, 100).unwrap();
            assert_ne!(d.instance, 1, "failed instance must not be routed to");
        }
        assert_eq!(r.queued_tokens[1], 0);
        // recovery restores routing: the recovered slot is least-loaded
        r.set_failed(1, false);
        assert!(r.is_active(1));
        assert_eq!(r.route(99, 1).unwrap().instance, 1);
    }

    #[test]
    fn kv_centric_failed_home_forfeits_cache() {
        // the satellite distinction: *failed* homes (not just drained ones)
        // must forfeit KV-centric affinity — the local cache died with the
        // instance.
        let mut r = Router::new(RouterKind::KvCentric { overload_factor: 100.0 }, 2);
        let first = r.route(7, 100).unwrap();
        assert!(first.cache_usable);
        r.set_failed(first.instance, true);
        let again = r.route(7, 100).unwrap();
        assert_ne!(again.instance, first.instance);
        assert!(!again.cache_usable, "cache on a failed instance is gone");
        // the home moved to the live instance; recovery of the dead one
        // must not pull the session back
        r.set_failed(first.instance, false);
        let third = r.route(7, 100).unwrap();
        assert_eq!(third.instance, again.instance);
        assert!(third.cache_usable);
    }

    #[test]
    fn failed_and_drained_masks_are_orthogonal() {
        let mut r = Router::new(RouterKind::PeerToPeer, 2);
        // drained AND failed: recovery alone must not reactivate the slot
        r.set_active(0, false);
        r.set_failed(0, true);
        assert!(!r.is_active(0));
        r.set_failed(0, false);
        assert!(!r.is_active(0), "recovered slot is still drained");
        r.set_active(0, true);
        assert!(r.is_active(0));
    }

    #[test]
    fn donors_stay_admissible_for_prefill() {
        let mut r = Router::new(RouterKind::PeerToPeer, 2);
        r.set_donor(0, true);
        assert!(r.is_donor(0));
        assert!(r.is_active(0), "a donor keeps serving prefill traffic");
        assert_eq!(r.active_instances(), 2);
        // least-loaded routing still reaches the donor
        r.queued_tokens[1] = 10_000;
        assert_eq!(r.route(1, 100).unwrap().instance, 0);
        r.set_donor(0, false);
        assert_eq!(r.state(0), InstanceState::Active);
    }

    #[test]
    fn route_where_honors_predicate_and_falls_back() {
        let mut r = Router::new(RouterKind::PeerToPeer, 3);
        r.queued_tokens[0] = 10;
        r.queued_tokens[1] = 5_000;
        r.queued_tokens[2] = 6_000;
        // least-loaded is 0, but the predicate excludes it
        let d = r.route_where(1, 100, |i| i != 0).unwrap();
        assert_eq!(d.instance, 1);
        // a predicate that excludes everything degrades to plain routing
        let d = r.route_where(2, 100, |_| false).unwrap();
        assert_eq!(d.instance, 0);
    }

    #[test]
    fn rehoming_prefers_non_donor_instances() {
        let mut r = Router::new(RouterKind::PeerToPeer, 3);
        r.set_donor(0, true);
        // donor 0 is by far the least loaded, but re-homing avoids it
        r.queued_tokens[1] = 5_000;
        r.queued_tokens[2] = 6_000;
        let d = r.route_avoiding_donors(9, 100).unwrap();
        assert_eq!(d.instance, 1, "stranded work must land on a non-donor");
        // plain routing still honors pure least-loaded
        assert_eq!(r.route(9, 100).unwrap().instance, 0);
    }

    #[test]
    fn rehoming_falls_back_when_every_instance_donates() {
        let mut r = Router::new(RouterKind::PeerToPeer, 2);
        r.set_donor(0, true);
        r.set_donor(1, true);
        r.queued_tokens[1] = 50;
        let d = r.route_avoiding_donors(3, 10).unwrap();
        assert_eq!(d.instance, 0, "all-donor pool falls back to least-loaded");
    }

    #[test]
    #[should_panic(expected = "offload donor must be an Active prefill instance")]
    fn offload_never_targets_a_drained_instance() {
        let mut r = Router::new(RouterKind::PeerToPeer, 2);
        r.set_active(0, false);
        r.set_donor(0, true);
    }

    #[test]
    #[should_panic(expected = "offload donor must be an Active prefill instance")]
    fn offload_never_targets_a_failed_instance() {
        let mut r = Router::new(RouterKind::PeerToPeer, 2);
        r.set_failed(1, true);
        r.set_donor(1, true);
    }

    #[test]
    fn failed_donor_recovers_as_plain_active() {
        let mut r = Router::new(RouterKind::PeerToPeer, 2);
        r.set_donor(0, true);
        r.set_failed(0, true);
        assert!(r.is_failed(0));
        assert!(!r.is_donor(0), "failure strips the donor role");
        r.set_failed(0, false);
        assert_eq!(r.state(0), InstanceState::Active, "recovery must not resurrect donor state");
    }

    #[test]
    fn draining_a_donor_ends_its_donor_role() {
        let mut r = Router::new(RouterKind::PeerToPeer, 2);
        r.set_donor(0, true);
        r.set_active(0, false);
        assert_eq!(r.state(0), InstanceState::Drained);
        r.set_active(0, true);
        assert_eq!(r.state(0), InstanceState::Active);
    }

    #[test]
    fn affinity_routing_sticks_to_the_last_prefill_instance() {
        let mut r = Router::new(RouterKind::PeerToPeer, 4);
        let (first, local) = r.route_affinity(7, 100, 4.0).unwrap();
        assert!(!local, "a session's first turn has no affine instance");
        for _ in 0..5 {
            let (d, local) = r.route_affinity(7, 100, 4.0).unwrap();
            assert_eq!(d.instance, first.instance);
            assert!(local, "follow-up turns must land on the affine instance");
            assert!(d.cache_usable, "shared pool survives any placement");
        }
    }

    #[test]
    fn affinity_overload_falls_back_without_losing_the_pool() {
        let mut r = Router::new(RouterKind::PeerToPeer, 2);
        let (first, _) = r.route_affinity(7, 1_000_000, 1.0).unwrap();
        // the other instance is empty → the queue-ratio test reroutes
        let (again, local) = r.route_affinity(7, 100, 1.0).unwrap();
        assert_ne!(again.instance, first.instance);
        assert!(!local, "overloaded affine instance must be abandoned");
        assert!(again.cache_usable, "pool-held prefix stays fetchable");
        // the affinity hint follows the reroute
        let (third, local) = r.route_affinity(7, 100, 1.0).unwrap();
        assert_eq!(third.instance, again.instance);
        assert!(local);
    }

    #[test]
    fn affinity_skips_drained_and_failed_instances() {
        let mut r = Router::new(RouterKind::PeerToPeer, 3);
        let (first, _) = r.route_affinity(5, 100, 8.0).unwrap();
        r.set_failed(first.instance, true);
        let (again, local) = r.route_affinity(5, 100, 8.0).unwrap();
        assert_ne!(again.instance, first.instance);
        assert!(!local, "a dead affine instance holds no local blocks");
        assert!(again.cache_usable);
    }

    #[test]
    fn plain_route_ignores_affinity_state() {
        // route() must stay stateless even after affinity traffic: the
        // existing-scenario bit-exactness contract depends on it.
        let mut r = Router::new(RouterKind::PeerToPeer, 2);
        r.route_affinity(1, 10_000, 4.0).unwrap();
        let side = Router::new(RouterKind::PeerToPeer, 2);
        let mut expect = Router {
            kind: side.kind,
            queued_tokens: r.queued_tokens.clone(),
            state: vec![InstanceState::Active; 2],
            home: BTreeMap::new(),
            affinity: BTreeMap::new(),
        };
        assert_eq!(r.route(1, 100), expect.route(1, 100));
    }

    #[test]
    fn no_routable_capacity_returns_none_and_charges_nothing() {
        // the mass-failure satellite: zero routable instances must surface
        // as an explicit no-capacity signal, not a phantom route to slot 0.
        let mut r = Router::new(RouterKind::PeerToPeer, 3);
        r.set_failed(0, true);
        r.set_failed(1, true);
        r.set_active(2, false);
        assert_eq!(r.active_instances(), 0);
        assert_eq!(r.route(7, 100), None);
        assert_eq!(r.route_affinity(7, 100, 4.0), None);
        assert_eq!(r.route_where(7, 100, |_| true), None);
        assert_eq!(r.route_avoiding_donors(7, 100), None);
        assert!(
            r.queued_tokens.iter().all(|&q| q == 0),
            "a failed/drained fleet must accrue no queue charge: {:?}",
            r.queued_tokens
        );
        // capacity back → routing resumes and charges normally
        r.set_failed(0, false);
        let d = r.route(7, 100).expect("recovered slot is routable");
        assert_eq!(d.instance, 0);
        assert_eq!(r.queued_tokens[0], 100);
    }

    #[test]
    fn affinity_map_is_bounded_by_live_sessions() {
        // the unbounded-growth satellite: hints leave the map at session
        // terminal and when the affine instance fails.
        let mut r = Router::new(RouterKind::PeerToPeer, 4);
        for s in 0..100u64 {
            r.route_affinity(s, 100, 4.0).unwrap();
        }
        assert_eq!(r.tracked_sessions(), 100);
        // 60 sessions reach a terminal state
        for s in 0..60u64 {
            r.evict_session(s);
        }
        assert_eq!(r.tracked_sessions(), 40);
        // terminal eviction is idempotent
        r.evict_session(0);
        assert_eq!(r.tracked_sessions(), 40);
        // an instance failure drops exactly the hints pointing at it
        let at_0 = (60..100u64)
            .filter(|s| {
                let (d, _) = r.route_affinity(*s, 0, 4.0).unwrap();
                d.instance == 0
            })
            .count();
        assert!(at_0 > 0, "least-loaded over 4 slots must land some sessions on 0");
        r.set_failed(0, true);
        assert_eq!(r.tracked_sessions(), 40 - at_0);
    }

    #[test]
    fn evict_session_drops_kv_centric_home() {
        let mut r = Router::new(RouterKind::KvCentric { overload_factor: 4.0 }, 2);
        r.route(7, 100).unwrap();
        assert_eq!(r.tracked_sessions(), 1);
        r.evict_session(7);
        assert_eq!(r.tracked_sessions(), 0);
    }

    #[test]
    fn completion_reduces_queue() {
        let mut r = Router::new(RouterKind::PeerToPeer, 2);
        let d = r.route(0, 500).unwrap();
        r.complete(d.instance, 500);
        assert_eq!(r.queued_tokens[d.instance], 0);
        r.complete(d.instance, 10_000); // saturating
        assert_eq!(r.queued_tokens[d.instance], 0);
    }
}
