//! Request routing (paper §4.1): the peer-to-peer stateless scheduler vs
//! the KVCache-centric baseline.
//!
//! * **Peer-to-peer** (this paper): KV blocks live in the shared
//!   disaggregated pool, uniformly accessible over UB — so the router is
//!   *stateless* and free to pick the least-loaded prefill instance. Cache
//!   hits do not depend on placement.
//!
//! * **KVCache-centric** (Dynamo/Mooncake style): cached KV lives in a
//!   specific instance's local DRAM. The router must send a session back
//!   to its *home* instance to reuse cache; rerouting for load balance
//!   forfeits the cached prefix (recompute). This coupling is exactly the
//!   scheduling-complexity/load-balance tension §4.1 argues against.

use std::collections::BTreeMap;

/// Routing decision for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteDecision {
    pub instance: usize,
    /// Whether locally-held cache remains usable after this routing.
    pub cache_usable: bool,
}

/// Router behavior under comparison.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RouterKind {
    PeerToPeer,
    KvCentric {
        /// Queue-depth ratio (vs least-loaded) beyond which the KV-centric
        /// router abandons affinity and reroutes (losing the cache).
        overload_factor: f64,
    },
}

/// The router: tracks per-instance queued compute tokens.
#[derive(Debug)]
pub struct Router {
    pub kind: RouterKind,
    /// Outstanding queued tokens per prefill instance.
    pub queued_tokens: Vec<u64>,
    /// Which instance slots are currently serving the prefill role. The
    /// elastic autoscaler (paper §4.1 dynamic adjustment) activates and
    /// drains slots as NPUs move between the prefill and decode pools;
    /// inactive slots receive no traffic.
    active: Vec<bool>,
    /// Instance slots the failure detector has declared dead (chaos
    /// faults). Orthogonal to `active`: a drained slot left the prefill
    /// role voluntarily and keeps its flag when reactivated; a failed slot
    /// is masked out until recovery clears it, whatever its role state.
    failed: Vec<bool>,
    /// session → home instance (KV-centric affinity state; the P2P router
    /// keeps NO such state — that is the point).
    home: BTreeMap<u64, usize>,
}

impl Router {
    pub fn new(kind: RouterKind, n_instances: usize) -> Router {
        Router {
            kind,
            queued_tokens: vec![0; n_instances],
            active: vec![true; n_instances],
            failed: vec![false; n_instances],
            home: BTreeMap::new(),
        }
    }

    /// Mark an instance slot active (serving prefill) or draining/inactive.
    pub fn set_active(&mut self, instance: usize, on: bool) {
        self.active[instance] = on;
    }

    /// Mark an instance slot failed (failure detector) or recovered.
    /// Failed slots receive no traffic and — for the KV-centric baseline —
    /// forfeit every session home pointing at them, exactly like drained
    /// slots: the local cache died with the instance.
    pub fn set_failed(&mut self, instance: usize, failed: bool) {
        self.failed[instance] = failed;
    }

    pub fn is_failed(&self, instance: usize) -> bool {
        self.failed[instance]
    }

    /// Routable: serving the prefill role *and* not marked failed.
    pub fn is_active(&self, instance: usize) -> bool {
        self.active[instance] && !self.failed[instance]
    }

    pub fn active_instances(&self) -> usize {
        (0..self.active.len()).filter(|&i| self.is_active(i)).count()
    }

    fn least_loaded(&self) -> usize {
        self.queued_tokens
            .iter()
            .enumerate()
            .filter(|&(i, _)| self.is_active(i))
            .min_by_key(|&(_, &q)| q)
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Route a request; caller charges `tokens` of prefill work.
    pub fn route(&mut self, session: u64, tokens: u64) -> RouteDecision {
        let decision = match self.kind {
            RouterKind::PeerToPeer => {
                // stateless least-loaded; cache is in the shared pool, so
                // it survives any placement.
                RouteDecision { instance: self.least_loaded(), cache_usable: true }
            }
            RouterKind::KvCentric { overload_factor } => {
                let least = self.least_loaded();
                match self.home.get(&session) {
                    // a drained or failed home instance lost its local
                    // cache with it
                    Some(&home) if !self.is_active(home) => {
                        RouteDecision { instance: least, cache_usable: false }
                    }
                    Some(&home) => {
                        let home_q = self.queued_tokens[home] as f64;
                        let least_q = self.queued_tokens[least] as f64;
                        if home_q <= (least_q + tokens as f64) * overload_factor {
                            RouteDecision { instance: home, cache_usable: true }
                        } else {
                            // overload: reroute and lose the local cache
                            RouteDecision { instance: least, cache_usable: false }
                        }
                    }
                    None => RouteDecision { instance: least, cache_usable: true },
                }
            }
        };
        if let RouterKind::KvCentric { .. } = self.kind {
            self.home.insert(session, decision.instance);
        }
        self.queued_tokens[decision.instance] += tokens;
        decision
    }

    /// Work completed on an instance.
    pub fn complete(&mut self, instance: usize, tokens: u64) {
        self.queued_tokens[instance] = self.queued_tokens[instance].saturating_sub(tokens);
    }

    /// Load imbalance across *active* instances: max/mean queued tokens.
    pub fn imbalance(&self) -> f64 {
        let active: Vec<u64> = self
            .queued_tokens
            .iter()
            .enumerate()
            .filter(|&(i, _)| self.is_active(i))
            .map(|(_, &q)| q)
            .collect();
        let total: u64 = active.iter().sum();
        if total == 0 || active.is_empty() {
            return 1.0;
        }
        let mean = total as f64 / active.len() as f64;
        let max = *active.iter().max().unwrap() as f64;
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_balances_load() {
        let mut r = Router::new(RouterKind::PeerToPeer, 4);
        for s in 0..100u64 {
            r.route(s % 5, 1000); // 5 hot sessions
        }
        assert!(r.imbalance() < 1.1, "imbalance {}", r.imbalance());
    }

    #[test]
    fn kv_centric_hotspots_on_hot_sessions() {
        let mut r = Router::new(RouterKind::KvCentric { overload_factor: 8.0 }, 4);
        for s in 0..100u64 {
            r.route(s % 2, 1000); // 2 hot sessions pin 2 instances
        }
        assert!(r.imbalance() > 1.5, "imbalance {}", r.imbalance());
    }

    #[test]
    fn kv_centric_keeps_affinity_when_feasible() {
        let mut r = Router::new(RouterKind::KvCentric { overload_factor: 4.0 }, 2);
        let first = r.route(7, 100);
        assert!(first.cache_usable);
        let again = r.route(7, 100);
        assert_eq!(again.instance, first.instance);
        assert!(again.cache_usable);
    }

    #[test]
    fn kv_centric_reroute_loses_cache() {
        let mut r = Router::new(RouterKind::KvCentric { overload_factor: 1.0 }, 2);
        let first = r.route(7, 1_000_000);
        // other instance empty → overload triggers reroute
        let again = r.route(7, 100);
        assert_ne!(again.instance, first.instance);
        assert!(!again.cache_usable, "reroute must forfeit local cache");
    }

    #[test]
    fn p2p_cache_always_usable() {
        let mut r = Router::new(RouterKind::PeerToPeer, 2);
        r.route(1, 1_000_000);
        let d = r.route(1, 100);
        assert!(d.cache_usable);
    }

    #[test]
    fn inactive_instances_receive_no_traffic() {
        let mut r = Router::new(RouterKind::PeerToPeer, 3);
        r.set_active(1, false);
        for s in 0..30u64 {
            let d = r.route(s, 100);
            assert_ne!(d.instance, 1, "drained instance must not be routed to");
        }
        assert_eq!(r.queued_tokens[1], 0);
        assert_eq!(r.active_instances(), 2);
        // reactivation brings it back as the least-loaded target
        r.set_active(1, true);
        assert_eq!(r.route(99, 1).instance, 1);
    }

    #[test]
    fn kv_centric_drained_home_forfeits_cache() {
        let mut r = Router::new(RouterKind::KvCentric { overload_factor: 100.0 }, 2);
        let first = r.route(7, 100);
        r.set_active(first.instance, false);
        let again = r.route(7, 100);
        assert_ne!(again.instance, first.instance);
        assert!(!again.cache_usable, "cache on a drained instance is gone");
    }

    #[test]
    fn failed_instances_receive_no_traffic_until_recovered() {
        let mut r = Router::new(RouterKind::PeerToPeer, 3);
        r.set_failed(1, true);
        assert!(r.is_failed(1));
        assert!(!r.is_active(1), "failed slot must not be routable");
        assert_eq!(r.active_instances(), 2);
        for s in 0..30u64 {
            let d = r.route(s, 100);
            assert_ne!(d.instance, 1, "failed instance must not be routed to");
        }
        assert_eq!(r.queued_tokens[1], 0);
        // recovery restores routing: the recovered slot is least-loaded
        r.set_failed(1, false);
        assert!(r.is_active(1));
        assert_eq!(r.route(99, 1).instance, 1);
    }

    #[test]
    fn kv_centric_failed_home_forfeits_cache() {
        // the satellite distinction: *failed* homes (not just drained ones)
        // must forfeit KV-centric affinity — the local cache died with the
        // instance.
        let mut r = Router::new(RouterKind::KvCentric { overload_factor: 100.0 }, 2);
        let first = r.route(7, 100);
        assert!(first.cache_usable);
        r.set_failed(first.instance, true);
        let again = r.route(7, 100);
        assert_ne!(again.instance, first.instance);
        assert!(!again.cache_usable, "cache on a failed instance is gone");
        // the home moved to the live instance; recovery of the dead one
        // must not pull the session back
        r.set_failed(first.instance, false);
        let third = r.route(7, 100);
        assert_eq!(third.instance, again.instance);
        assert!(third.cache_usable);
    }

    #[test]
    fn failed_and_drained_masks_are_orthogonal() {
        let mut r = Router::new(RouterKind::PeerToPeer, 2);
        // drained AND failed: recovery alone must not reactivate the slot
        r.set_active(0, false);
        r.set_failed(0, true);
        assert!(!r.is_active(0));
        r.set_failed(0, false);
        assert!(!r.is_active(0), "recovered slot is still drained");
        r.set_active(0, true);
        assert!(r.is_active(0));
    }

    #[test]
    fn completion_reduces_queue() {
        let mut r = Router::new(RouterKind::PeerToPeer, 2);
        let d = r.route(0, 500);
        r.complete(d.instance, 500);
        assert_eq!(r.queued_tokens[d.instance], 0);
        r.complete(d.instance, 10_000); // saturating
        assert_eq!(r.queued_tokens[d.instance], 0);
    }
}
