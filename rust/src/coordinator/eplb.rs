//! Expert-parallelism load balancing (EPLB; §4.1, §5.1).
//!
//! The decode deployment hosts 256 router experts + 32 redundant replicas +
//! 32 shared-expert copies across 320 ranks (one expert per die). EPLB
//! decides which experts get replicas and how token load spreads across
//! replicas; its output — the residual imbalance factor — feeds the decode
//! pipeline model (`eplb_imbalance`), connecting skewed activations to the
//! Table 3/4 "Default vs Perfect EPLB" gap.

/// Placement of experts (with replicas) onto EP ranks.
#[derive(Debug, Clone)]
pub struct ExpertPlacement {
    pub n_experts: usize,
    pub n_ranks: usize,
    /// replicas[e] = number of ranks hosting expert e (>= 1).
    pub replicas: Vec<usize>,
}

/// Greedy EPLB: give every expert one rank, then hand the `redundant`
/// extra ranks to the experts with the highest per-replica load.
pub fn place_experts(load: &[u64], n_ranks: usize, redundant: usize) -> ExpertPlacement {
    let n_experts = load.len();
    assert!(n_ranks >= n_experts + redundant, "not enough ranks");
    let mut replicas = vec![1usize; n_experts];
    for _ in 0..redundant {
        // expert with max load-per-replica gets another replica
        let (best, _) = load
            .iter()
            .enumerate()
            .map(|(e, &l)| (e, l as f64 / replicas[e] as f64))
            .fold((0usize, -1.0f64), |acc, (e, v)| if v > acc.1 { (e, v) } else { acc });
        replicas[best] += 1;
    }
    ExpertPlacement { n_experts, n_ranks, replicas }
}

impl ExpertPlacement {
    /// Residual imbalance: max rank load / mean rank load, assuming each
    /// expert's tokens split evenly across its replicas.
    pub fn imbalance(&self, load: &[u64]) -> f64 {
        let total: f64 = load.iter().map(|&l| l as f64).sum();
        if total == 0.0 {
            return 1.0;
        }
        let used_ranks: usize = self.replicas.iter().sum();
        let mean = total / used_ranks as f64;
        let max = load
            .iter()
            .zip(&self.replicas)
            .map(|(&l, &r)| l as f64 / r as f64)
            .fold(0.0f64, f64::max);
        (max / mean).max(1.0)
    }
}

/// Multi-expert-per-rank packing for small deployments (ranks < experts):
/// longest-processing-time (LPT) greedy assignment; returns the residual
/// imbalance (max rank load / mean rank load).
pub fn lpt_imbalance(load: &[u64], n_ranks: usize) -> f64 {
    let total: f64 = load.iter().map(|&l| l as f64).sum();
    if total == 0.0 || n_ranks == 0 {
        return 1.0;
    }
    let mut order: Vec<usize> = (0..load.len()).collect();
    order.sort_unstable_by_key(|&e| std::cmp::Reverse(load[e]));
    let mut rank_load = vec![0f64; n_ranks];
    for e in order {
        let (idx, _) = rank_load
            .iter()
            .enumerate()
            .fold((0usize, f64::INFINITY), |acc, (i, &l)| if l < acc.1 { (i, l) } else { acc });
        rank_load[idx] += load[e] as f64;
    }
    let mean = total / n_ranks as f64;
    let max = rank_load.iter().cloned().fold(0.0, f64::max);
    (max / mean).max(1.0)
}

/// Residual imbalance for any deployment size: replica placement when the
/// rank budget allows one-expert-per-rank (+redundancy), LPT packing
/// otherwise.
pub fn deployment_imbalance(load: &[u64], n_ranks: usize, redundant: usize) -> f64 {
    if n_ranks >= load.len() + redundant {
        place_experts(load, n_ranks, redundant).imbalance(load)
    } else {
        lpt_imbalance(load, n_ranks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ExpertActivation;

    #[test]
    fn uniform_load_is_balanced() {
        let load = vec![100u64; 16];
        let p = place_experts(&load, 20, 4);
        assert!((p.imbalance(&load) - 1.25).abs() < 0.3); // replicas skew mean a bit
        assert_eq!(p.replicas.iter().sum::<usize>(), 20);
    }

    #[test]
    fn redundancy_goes_to_hot_experts() {
        let mut load = vec![10u64; 8];
        load[0] = 1000;
        load[1] = 500;
        let p = place_experts(&load, 12, 4);
        assert!(p.replicas[0] >= 2, "hottest expert should be replicated: {:?}", p.replicas);
        assert!(p.replicas[0] >= p.replicas[2]);
    }

    #[test]
    fn redundancy_reduces_imbalance() {
        let mut ea = ExpertActivation::new(11, 256, 1.1);
        let load = ea.batch_histogram(30_720, 8);
        let none = place_experts(&load, 256, 0);
        let some = place_experts(&load, 320, 64);
        let i_none = none.imbalance(&load);
        let i_some = some.imbalance(&load);
        assert!(
            i_some < i_none,
            "redundant replicas should cut imbalance: {i_none:.2} -> {i_some:.2}"
        );
        assert!(i_none > 1.5, "skewed load should start imbalanced: {i_none:.2}");
    }

    #[test]
    #[should_panic(expected = "not enough ranks")]
    fn rejects_undersized_deployment() {
        place_experts(&[1, 2, 3], 3, 1);
    }

    #[test]
    fn lpt_balances_uniform_load() {
        let load = vec![10u64; 16];
        let i = lpt_imbalance(&load, 4);
        assert!((i - 1.0).abs() < 1e-9, "{i}");
    }

    #[test]
    fn lpt_handles_skew_better_than_random() {
        let mut load = vec![1u64; 16];
        load[0] = 100;
        // 4 ranks; LPT puts the hot expert alone-ish: max rank ≈ 100+...
        let i = lpt_imbalance(&load, 4);
        let mean = 115.0 / 4.0;
        assert!(i >= 100.0 / mean - 1e-9);
        assert!(i < 110.0 / mean, "{i}");
    }

    #[test]
    fn deployment_imbalance_dispatches_both_regimes() {
        let load = vec![5u64; 8];
        // big deployment → replica path
        let big = deployment_imbalance(&load, 12, 4);
        // tiny deployment → LPT path
        let tiny = deployment_imbalance(&load, 4, 0);
        assert!(big >= 1.0 && tiny >= 1.0);
        assert!((tiny - 1.0).abs() < 1e-9);
    }

    #[test]
    fn lpt_degenerate_inputs() {
        assert_eq!(lpt_imbalance(&[], 4), 1.0);
        assert_eq!(lpt_imbalance(&[0, 0], 4), 1.0);
        assert_eq!(lpt_imbalance(&[5], 0), 1.0);
    }
}
