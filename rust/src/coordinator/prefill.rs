//! Prefill engine (paper §4.3): one prefill *instance* = 16 NPUs (EP32)
//! batching queued requests, running the staged hybrid-parallel MLA +
//! microbatch pipeline, integrating context-cache reuse.

use crate::config::{Ascend910cDie, DeepSeekDims, ServingConfig};
use crate::simnpu::pipeline::{prefill_model, PrefillPoint};
use crate::Micros;

/// A prefill batch about to run on one instance.
#[derive(Debug, Clone)]
pub struct PrefillBatch {
    pub requests: Vec<u64>,
    /// Tokens actually computed (post cache-reuse).
    pub compute_tokens: usize,
    /// Tokens covered by context-cache hits (fetched, not computed).
    pub reused_tokens: usize,
    /// Mean prompt length (drives the attention quadratic term).
    pub mean_prompt: usize,
}

/// One prefill instance: queue + busy state.
#[derive(Debug)]
pub struct PrefillInstance {
    pub id: usize,
    pub npus: usize,
    pub busy_until: Micros,
    /// Queued (request, compute_tokens, prompt_len).
    pub queue: Vec<(u64, usize, usize)>,
    pub total_prompt_tokens: u64,
    pub total_compute_tokens: u64,
}

impl PrefillInstance {
    pub fn new(id: usize, npus: usize) -> Self {
        PrefillInstance {
            id,
            npus,
            busy_until: 0.0,
            queue: Vec::new(),
            total_prompt_tokens: 0,
            total_compute_tokens: 0,
        }
    }

    pub fn enqueue(&mut self, req: u64, compute_tokens: usize, prompt_len: usize) {
        self.queue.push((req, compute_tokens, prompt_len));
    }

    /// Form the next batch up to `tokens_per_npu x npus` compute tokens.
    pub fn form_batch(&mut self, tokens_per_npu: usize) -> Option<PrefillBatch> {
        if self.queue.is_empty() {
            return None;
        }
        let budget = tokens_per_npu * self.npus;
        let mut requests = Vec::new();
        let mut compute = 0usize;
        let mut reused = 0usize;
        let mut prompt_sum = 0usize;
        while let Some(&(req, ct, pl)) = self.queue.first() {
            if !requests.is_empty() && compute + ct > budget {
                break;
            }
            self.queue.remove(0);
            requests.push(req);
            compute += ct;
            reused += pl.saturating_sub(ct);
            prompt_sum += pl;
            if compute >= budget {
                break;
            }
        }
        let n = requests.len().max(1);
        self.total_compute_tokens += compute as u64;
        self.total_prompt_tokens += (compute + reused) as u64;
        Some(PrefillBatch {
            requests,
            compute_tokens: compute,
            reused_tokens: reused,
            mean_prompt: prompt_sum / n,
        })
    }
}

/// Latency of one prefill batch on an instance (µs).
///
/// Reused tokens skip compute but are fetched from the pool — the fetch
/// cost is charged by the caller (context-cache lookup); here we time the
/// compute of the non-reused suffix tokens.
pub fn batch_latency_us(
    die: &Ascend910cDie,
    model: &DeepSeekDims,
    serving: &ServingConfig,
    batch: &PrefillBatch,
    npus: usize,
    eplb_imbalance: f64,
) -> Micros {
    let tokens_per_npu = batch.compute_tokens.div_ceil(npus).max(1);
    let point = PrefillPoint {
        prompt_len: batch.mean_prompt.max(1),
        tokens_per_npu,
        ep: serving.prefill_ep_degree(),
        microbatch: serving.microbatch,
        hybrid_parallelism: serving.hybrid_parallelism,
        length_skew: 1.35,
        eplb_imbalance,
    };
    prefill_model(die, model, &point).batch_us
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> (Ascend910cDie, DeepSeekDims, ServingConfig) {
        (Ascend910cDie::default(), DeepSeekDims::deepseek_r1(), ServingConfig::paper_default())
    }

    #[test]
    fn batch_formation_respects_budget() {
        let mut inst = PrefillInstance::new(0, 16);
        for i in 0..10 {
            inst.enqueue(i, 4096, 4096);
        }
        let b = inst.form_batch(16384).unwrap();
        // 16 NPUs x 16K tokens = 256K budget → all 10 x 4K = 40K fit
        assert_eq!(b.requests.len(), 10);
        assert_eq!(b.compute_tokens, 40960);
    }

    #[test]
    fn oversized_request_still_batches_alone() {
        let mut inst = PrefillInstance::new(0, 1);
        inst.enqueue(0, 50_000, 50_000);
        let b = inst.form_batch(16384).unwrap();
        assert_eq!(b.requests, vec![0]);
    }

    #[test]
    fn reuse_reduces_latency() {
        let (die, m, s) = env();
        let full = PrefillBatch {
            requests: vec![0],
            compute_tokens: 65536,
            reused_tokens: 0,
            mean_prompt: 4096,
        };
        let half = PrefillBatch {
            requests: vec![0],
            compute_tokens: 32768,
            reused_tokens: 32768,
            mean_prompt: 4096,
        };
        let t_full = batch_latency_us(&die, &m, &s, &full, 16, 1.1);
        let t_half = batch_latency_us(&die, &m, &s, &half, 16, 1.1);
        assert!(t_half < t_full * 0.65, "t_half {t_half} vs t_full {t_full}");
    }

    #[test]
    fn empty_queue_no_batch() {
        let mut inst = PrefillInstance::new(0, 16);
        assert!(inst.form_batch(16384).is_none());
    }

    #[test]
    fn fifo_order_preserved() {
        let mut inst = PrefillInstance::new(0, 1);
        inst.enqueue(10, 8000, 8000);
        inst.enqueue(11, 8000, 8000);
        inst.enqueue(12, 8000, 8000);
        let b = inst.form_batch(16000).unwrap();
        assert_eq!(b.requests, vec![10, 11]);
        let b2 = inst.form_batch(16000).unwrap();
        assert_eq!(b2.requests, vec![12]);
    }
}
