//! Decode engine (paper §4.2): the LEP EP320 decode instance as a slotted
//! continuous-batching stepper with the two-stream microbatch pipeline and
//! pipelined MTP.

use crate::config::{Ascend910cDie, DeepSeekDims, ServingConfig};
use crate::simnpu::pipeline::{decode_step, DecodePoint, DecodeStepModel};
use crate::util::Rng;

/// One active decode slot.
#[derive(Debug, Clone, Copy)]
pub struct Slot {
    pub request: u64,
    /// Current context length (prompt + generated so far).
    pub kv_len: usize,
    pub remaining_tokens: usize,
    /// SLO tier the request belongs to (0 = the deployment's base SLO).
    pub slo_tier: usize,
}

/// The decode instance: slot array + step dynamics.
#[derive(Debug)]
pub struct DecodeInstance {
    pub npus: usize,
    pub slots: Vec<Slot>,
    pub max_concurrent: usize,
    pub steps: u64,
    pub tokens_emitted: u64,
    /// Slot-step opportunities: one per active slot per step. With MTP on,
    /// `(tokens_emitted - slot_steps) / slot_steps` is the *measured*
    /// speculative acceptance rate (report: `mtp_acceptance`); with MTP
    /// off it is exactly zero.
    pub slot_steps: u64,
    rng: Rng,
}

/// Tokens emitted for one request in one step.
#[derive(Debug, Clone, Copy)]
pub struct SlotEmit {
    pub request: u64,
    pub tokens: usize,
    pub finished: bool,
}

impl DecodeInstance {
    pub fn new(npus: usize, max_concurrent: usize, seed: u64) -> Self {
        DecodeInstance {
            npus,
            slots: Vec::new(),
            max_concurrent,
            steps: 0,
            tokens_emitted: 0,
            slot_steps: 0,
            rng: Rng::new(seed),
        }
    }

    pub fn free_slots(&self) -> usize {
        self.max_concurrent.saturating_sub(self.slots.len())
    }

    pub fn admit(&mut self, request: u64, prompt_len: usize, output_tokens: usize) {
        self.admit_tiered(request, prompt_len, output_tokens, 0);
    }

    /// Admit a request carrying its SLO tier (mixed-SLO batching).
    pub fn admit_tiered(
        &mut self,
        request: u64,
        prompt_len: usize,
        output_tokens: usize,
        slo_tier: usize,
    ) {
        assert!(self.free_slots() > 0, "admitting into a full instance");
        self.slots.push(Slot {
            request,
            kv_len: prompt_len,
            remaining_tokens: output_tokens,
            slo_tier,
        });
    }

    /// Resize the instance's NPU pool (elastic resplits). `batch_per_npu`
    /// is the SLO-derived per-NPU concurrency; the slot cap follows the new
    /// size. Active slots above the new cap are retained — the instance
    /// simply stops admitting until generation drains it below the cap.
    pub fn resize(&mut self, npus: usize, batch_per_npu: usize) {
        self.npus = npus;
        self.max_concurrent = batch_per_npu * npus;
    }

    /// Occupancy in [0, 1] relative to the current concurrency cap.
    pub fn occupancy(&self) -> f64 {
        if self.max_concurrent == 0 {
            return 1.0;
        }
        (self.slots.len() as f64 / self.max_concurrent as f64).min(1.0)
    }

    /// Batch per NPU implied by current occupancy. A zero-NPU instance
    /// (shrunk away by a resplit while its last slots drain) degrades to
    /// batch-per-NPU = slot count.
    pub fn batch_per_npu(&self) -> usize {
        self.slots.len().div_ceil(self.npus.max(1)).max(1)
    }

    /// Mean KV length across active slots.
    pub fn mean_kv_len(&self) -> usize {
        if self.slots.is_empty() {
            return 0;
        }
        self.slots.iter().map(|s| s.kv_len).sum::<usize>() / self.slots.len()
    }

    /// The instance's current operating point for the step-latency models
    /// (also the input to the §6.2.1 offload model).
    pub fn decode_point(&self, serving: &ServingConfig, eplb_imbalance: f64) -> DecodePoint {
        DecodePoint {
            batch_per_npu: self.batch_per_npu(),
            kv_len: self.mean_kv_len().max(1),
            ep: serving.decode_ep_degree(),
            microbatch: serving.microbatch,
            mtp: serving.mtp,
            mtp_acceptance: serving.mtp_acceptance,
            eplb_imbalance,
        }
    }

    /// Model the latency of the next step at current occupancy.
    pub fn step_model(
        &self,
        die: &Ascend910cDie,
        model: &DeepSeekDims,
        serving: &ServingConfig,
        eplb_imbalance: f64,
    ) -> DecodeStepModel {
        decode_step(die, model, &self.decode_point(serving, eplb_imbalance))
    }

    /// Execute one decode step: every slot emits 1 token, plus a second
    /// speculative token accepted with probability `mtp_acceptance`
    /// (§4.2.4 validation). Finished slots are removed.
    ///
    /// Returns per-slot emissions (the sim layer assigns timestamps).
    pub fn step(&mut self, serving: &ServingConfig) -> Vec<SlotEmit> {
        self.steps += 1;
        let mut emits = Vec::with_capacity(self.slots.len());
        let mut i = 0;
        while i < self.slots.len() {
            self.slot_steps += 1;
            let slot = &mut self.slots[i];
            let mut produced = 1usize;
            if serving.mtp
                && slot.remaining_tokens > 1
                && self.rng.f64() < serving.mtp_acceptance
            {
                produced = 2;
            }
            let produced = produced.min(slot.remaining_tokens);
            slot.remaining_tokens -= produced;
            slot.kv_len += produced;
            let finished = slot.remaining_tokens == 0;
            emits.push(SlotEmit { request: slot.request, tokens: produced, finished });
            self.tokens_emitted += produced as u64;
            if finished {
                self.slots.swap_remove(i);
            } else {
                i += 1;
            }
        }
        emits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> (Ascend910cDie, DeepSeekDims, ServingConfig) {
        (Ascend910cDie::default(), DeepSeekDims::deepseek_r1(), ServingConfig::paper_default())
    }

    #[test]
    fn admit_and_capacity() {
        let mut d = DecodeInstance::new(4, 8, 1);
        assert_eq!(d.free_slots(), 8);
        d.admit(1, 100, 10);
        d.admit(2, 200, 10);
        assert_eq!(d.free_slots(), 6);
        assert_eq!(d.batch_per_npu(), 1);
        assert_eq!(d.mean_kv_len(), 150);
    }

    #[test]
    fn step_emits_and_finishes() {
        let (_, _, mut s) = env();
        s.mtp = false;
        let mut d = DecodeInstance::new(1, 4, 2);
        d.admit(7, 10, 2);
        let e1 = d.step(&s);
        assert_eq!(e1.len(), 1);
        assert_eq!(e1[0].tokens, 1);
        assert!(!e1[0].finished);
        let e2 = d.step(&s);
        assert!(e2[0].finished);
        assert!(d.slots.is_empty());
        assert_eq!(d.tokens_emitted, 2);
    }

    #[test]
    fn mtp_emits_extra_tokens_at_acceptance_rate() {
        let (_, _, mut s) = env();
        s.mtp = true;
        s.mtp_acceptance = 0.7;
        let mut d = DecodeInstance::new(1, 512, 3);
        for i in 0..500 {
            d.admit(i, 100, 1_000_000);
        }
        let mut total = 0usize;
        for _ in 0..20 {
            total += d.step(&s).iter().map(|e| e.tokens).sum::<usize>();
        }
        let per_step = total as f64 / 20.0 / 500.0;
        assert!((per_step - 1.7).abs() < 0.05, "tokens/slot/step {per_step}");
        // the slot-step counter yields the measured acceptance rate
        assert_eq!(d.slot_steps, 20 * 500);
        let measured = (d.tokens_emitted - d.slot_steps) as f64 / d.slot_steps as f64;
        assert!((measured - 0.7).abs() < 0.05, "measured acceptance {measured}");
    }

    #[test]
    fn kv_grows_with_generation() {
        let (_, _, mut s) = env();
        s.mtp = false;
        let mut d = DecodeInstance::new(1, 4, 4);
        d.admit(1, 100, 50);
        for _ in 0..10 {
            d.step(&s);
        }
        assert_eq!(d.slots[0].kv_len, 110);
        assert_eq!(d.slots[0].remaining_tokens, 40);
    }

    #[test]
    fn step_model_slows_with_occupancy() {
        let (die, m, s) = env();
        let mut small = DecodeInstance::new(160, 20_000, 5);
        let mut big = DecodeInstance::new(160, 20_000, 5);
        for i in 0..160 * 8 {
            small.admit(i, 4096, 100);
        }
        for i in 0..160 * 96 {
            big.admit(i, 4096, 100);
        }
        let t_small = small.step_model(&die, &m, &s, 1.05).step_us;
        let t_big = big.step_model(&die, &m, &s, 1.05).step_us;
        assert!(t_big > t_small, "{t_small} vs {t_big}");
    }

    #[test]
    #[should_panic(expected = "admitting into a full instance")]
    fn overadmission_panics() {
        let mut d = DecodeInstance::new(1, 1, 6);
        d.admit(1, 10, 10);
        d.admit(2, 10, 10);
    }

    #[test]
    fn resize_moves_cap_and_keeps_slots() {
        let (_, _, mut s) = env();
        s.mtp = false;
        let mut d = DecodeInstance::new(4, 16, 7);
        for i in 0..8 {
            d.admit_tiered(i, 100, 10, (i % 2) as usize);
        }
        assert_eq!(d.free_slots(), 8);
        // shrink below occupancy: no free slots, nothing evicted
        d.resize(1, 4);
        assert_eq!(d.max_concurrent, 4);
        assert_eq!(d.free_slots(), 0);
        assert_eq!(d.slots.len(), 8);
        assert!((d.occupancy() - 1.0).abs() < 1e-9);
        // generation still progresses on retained slots
        let emits = d.step(&s);
        assert_eq!(emits.len(), 8);
        // grow back: cap scales with npus x batch
        d.resize(8, 4);
        assert_eq!(d.max_concurrent, 32);
        assert_eq!(d.free_slots(), 24);
        assert_eq!(d.slots[1].slo_tier, 1);
    }
}
