//! Prefill→decode KV-cache transfer (paper §4.3.3): RDMA-plane isolation,
//! asynchronous scheduling, and the deterministic group-connection mapping
//! that spreads decode ranks across prefill source ranks.

use crate::config::DeepSeekDims;
use crate::netsim::NetSim;
use crate::Micros;

/// The §4.3.3 deterministic group-connection mapping.
///
/// Given prefill TP size and decode TP/DP sizes, each decode rank pulls its
/// KV copy from prefill rank:
///   ratio      = prefill_tp / decode_tp
///   group_size = decode_dp / ratio
///   group_id   = decode_dp_rank / group_size
///   src        = group_id * decode_tp + decode_tp_rank
pub fn prefill_source_rank(
    prefill_tp: usize,
    decode_tp: usize,
    decode_dp: usize,
    decode_tp_rank: usize,
    decode_dp_rank: usize,
) -> usize {
    assert!(prefill_tp >= decode_tp && prefill_tp % decode_tp == 0);
    let ratio = prefill_tp / decode_tp;
    let group_size = (decode_dp / ratio).max(1);
    let group_id = decode_dp_rank / group_size;
    group_id * decode_tp + decode_tp_rank
}

/// Count of decode ranks mapped to each prefill rank (hotspot check).
pub fn connection_histogram(
    prefill_tp: usize,
    decode_tp: usize,
    decode_dp: usize,
) -> Vec<usize> {
    let mut h = vec![0usize; prefill_tp];
    for dp in 0..decode_dp {
        for tp in 0..decode_tp {
            let src = prefill_source_rank(prefill_tp, decode_tp, decode_dp, tp, dp);
            h[src] += 1;
        }
    }
    h
}

/// One KV transfer's modeled cost.
#[derive(Debug, Clone, Copy)]
pub struct TransferCost {
    pub bytes: u64,
    pub rdma_us: Micros,
    /// What the same transfer would cost if (incorrectly) routed over the
    /// UB plane, stealing decode bandwidth — the §4.3.3 isolation argument.
    pub ub_equivalent_us: Micros,
}

/// Cost of moving one request's KV cache from prefill to decode.
pub fn kv_transfer(net: &NetSim, model: &DeepSeekDims, prompt_tokens: usize) -> TransferCost {
    let bytes = model.kv_bytes_per_token() * prompt_tokens as u64;
    TransferCost {
        bytes,
        rdma_us: net.rdma.transfer_us(bytes),
        ub_equivalent_us: net
            .transfer_us(
                crate::netsim::Plane::Ub,
                crate::netsim::PathKind::NpuToNpu,
                crate::netsim::OpKind::Write,
                crate::netsim::Locality::InterNode,
                bytes,
            ),
    }
}

/// Asynchronous transfer scheduler state: the background thread of §4.3.3.
/// Tracks in-flight transfers; decode scheduling is never blocked by it.
#[derive(Debug, Default)]
pub struct TransferScheduler {
    /// (request, completion time)
    in_flight: Vec<(u64, Micros)>,
    pub completed: u64,
    pub total_bytes: u64,
}

impl TransferScheduler {
    /// Begin a transfer at `now`; returns its completion time.
    pub fn begin(&mut self, req: u64, now: Micros, cost: &TransferCost) -> Micros {
        // per-request RDMA streams are independent (dedicated plane): no
        // queueing against decode traffic; concurrent transfers share the
        // per-die NIC only when they collide on a die, which the group
        // mapping prevents — modeled as independent.
        let done = now + cost.rdma_us;
        self.in_flight.push((req, done));
        self.total_bytes += cost.bytes;
        done
    }

    /// Pop transfers completed by `now`.
    pub fn poll(&mut self, now: Micros) -> Vec<u64> {
        let mut done = Vec::new();
        self.in_flight.retain(|&(req, t)| {
            if t <= now {
                done.push(req);
                false
            } else {
                true
            }
        });
        self.completed += done.len() as u64;
        done
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapping_matches_paper_formula() {
        // prefill TP 32, decode TP 8, decode DP 16 → ratio 4, group_size 4
        let src = prefill_source_rank(32, 8, 16, 3, 9);
        // group_id = 9/4 = 2; src = 2*8 + 3 = 19
        assert_eq!(src, 19);
    }

    #[test]
    fn mapping_balances_connections() {
        // every prefill rank should serve the same number of decode ranks
        let h = connection_histogram(32, 8, 16);
        let max = *h.iter().max().unwrap();
        let min = *h.iter().min().unwrap();
        assert_eq!(max, min, "hotspot in connection mapping: {h:?}");
    }

    #[test]
    fn naive_mapping_would_hotspot() {
        // all decode ranks pulling from rank (decode_tp_rank) — the naive
        // scheme §4.3.3 warns about — concentrates decode_dp connections
        // on decode_tp prefill ranks.
        let mut naive = vec![0usize; 32];
        for _dp in 0..16 {
            for tp in 0..8 {
                naive[tp] += 1;
            }
        }
        let balanced = connection_histogram(32, 8, 16);
        let naive_max = *naive.iter().max().unwrap();
        let bal_max = *balanced.iter().max().unwrap();
        assert!(naive_max > bal_max * 2);
    }

    #[test]
    fn kv_bytes_and_rdma_cost() {
        let net = NetSim::default();
        let m = DeepSeekDims::deepseek_r1();
        let c = kv_transfer(&net, &m, 4096);
        // 4K tokens x 61 layers x 576 dims x 2B ≈ 288 MB
        assert!((c.bytes as f64 - 287.8e6).abs() / 287.8e6 < 0.01, "{}", c.bytes);
        // 288 MB over 25 GB/s ≈ 11.5 ms — transferred once per request, so
        // RDMA is not a bottleneck (the §4.3.3 claim)
        assert!(c.rdma_us > 10_000.0 && c.rdma_us < 14_000.0, "{}", c.rdma_us);
        // UB would be faster but steals decode bandwidth
        assert!(c.ub_equivalent_us < c.rdma_us);
    }

    #[test]
    fn scheduler_poll_semantics() {
        let net = NetSim::default();
        let m = DeepSeekDims::deepseek_r1();
        let mut ts = TransferScheduler::default();
        let c = kv_transfer(&net, &m, 1024);
        let done_at = ts.begin(1, 0.0, &c);
        assert_eq!(ts.in_flight(), 1);
        assert!(ts.poll(done_at - 1.0).is_empty());
        assert_eq!(ts.poll(done_at + 1.0), vec![1]);
        assert_eq!(ts.in_flight(), 0);
        assert_eq!(ts.completed, 1);
    }
}
