//! Workload generation: the dynamic, heterogeneous request patterns the
//! paper's §1/§4.1 motivate — bursty Poisson arrivals, log-normal
//! prompt/output lengths, multi-turn sessions with shared prefixes, and
//! Zipf-skewed expert activation.

use crate::util::Rng;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time, µs from run start.
    pub arrival_us: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    /// Tokens of the prompt (only generated when prefix caching matters;
    /// empty means "synthetic lengths only").
    pub prompt: Vec<i32>,
    /// Session this request belongs to (multi-turn reuse).
    pub session: u64,
    /// Turn index within the session.
    pub turn: u32,
}

/// Workload shape parameters.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub seed: u64,
    /// Mean request inter-arrival time, µs. Poisson process.
    pub mean_interarrival_us: f64,
    /// Burstiness: probability that an arrival spawns a burst…
    pub burst_prob: f64,
    /// …of this mean size (geometric).
    pub burst_mean: f64,
    /// Log-normal prompt length: ln-space mean and sigma.
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    pub min_prompt: usize,
    pub max_prompt: usize,
    /// Log-normal output length parameters.
    pub output_mu: f64,
    pub output_sigma: f64,
    pub min_output: usize,
    pub max_output: usize,
    /// Fraction of requests continuing an existing session (prefix reuse).
    pub multi_turn_prob: f64,
    /// Session-popularity skew (Zipf alpha; 0 = uniform). Hot sessions are
    /// what make cache-affinity routing hotspot (§4.1).
    pub session_skew: f64,
    /// Whether to materialize prompt token ids (needed for cache tests).
    pub materialize_tokens: bool,
    /// Vocabulary for materialized tokens.
    pub vocab: usize,
}

impl WorkloadSpec {
    /// A 4K-ish prompt / 256-output mix at moderate load (Table 4/5 style).
    pub fn paper_default(seed: u64) -> Self {
        WorkloadSpec {
            seed,
            mean_interarrival_us: 2_000.0,
            burst_prob: 0.05,
            burst_mean: 6.0,
            prompt_mu: (4096.0f64).ln() - 0.18,
            prompt_sigma: 0.6,
            min_prompt: 64,
            max_prompt: 16384,
            output_mu: (256.0f64).ln() - 0.08,
            output_sigma: 0.4,
            min_output: 16,
            max_output: 2048,
            multi_turn_prob: 0.45,
            session_skew: 0.0,
            materialize_tokens: false,
            vocab: 2048,
        }
    }

    /// Small trace sized for the real-model E2E examples.
    pub fn e2e_small(seed: u64, prefill_seq: usize, vocab: usize) -> Self {
        WorkloadSpec {
            seed,
            mean_interarrival_us: 30_000.0,
            burst_prob: 0.15,
            burst_mean: 3.0,
            prompt_mu: (prefill_seq as f64 * 0.5).ln(),
            prompt_sigma: 0.4,
            min_prompt: 8,
            max_prompt: prefill_seq,
            output_mu: (24.0f64).ln(),
            output_sigma: 0.3,
            min_output: 4,
            max_output: 48,
            multi_turn_prob: 0.5,
            session_skew: 0.0,
            materialize_tokens: true,
            vocab,
        }
    }
}

/// Session state for multi-turn prefix construction.
struct Session {
    id: u64,
    history: Vec<i32>,
    turns: u32,
}

/// Generate a trace of `n` requests.
pub fn generate(spec: &WorkloadSpec, n: usize) -> Vec<Request> {
    let mut rng = Rng::new(spec.seed);
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    let mut sessions: Vec<Session> = Vec::new();
    let mut next_session = 0u64;
    let mut burst_left = 0usize;

    for id in 0..n as u64 {
        if burst_left > 0 {
            burst_left -= 1;
            t += rng.exponential(spec.mean_interarrival_us * 0.05);
        } else {
            t += rng.exponential(spec.mean_interarrival_us);
            if rng.f64() < spec.burst_prob {
                burst_left = (rng.exponential(spec.burst_mean) as usize).clamp(1, 64);
            }
        }

        let prompt_len = (rng.lognormal(spec.prompt_mu, spec.prompt_sigma) as usize)
            .clamp(spec.min_prompt, spec.max_prompt);
        let output_len = (rng.lognormal(spec.output_mu, spec.output_sigma) as usize)
            .clamp(spec.min_output, spec.max_output);

        // multi-turn: continue a random session, prefix = its history
        let reuse = !sessions.is_empty() && rng.f64() < spec.multi_turn_prob;
        let (session, turn, prompt) = if reuse {
            let idx = if spec.session_skew > 0.0 {
                rng.zipf(sessions.len() as u64, spec.session_skew) as usize
            } else {
                rng.below(sessions.len() as u64) as usize
            };
            let s = &mut sessions[idx];
            s.turns += 1;
            let mut prompt = Vec::new();
            if spec.materialize_tokens {
                prompt = s.history.clone();
                let new_part = prompt_len.saturating_sub(prompt.len()).max(1);
                for _ in 0..new_part {
                    prompt.push(rng.below(spec.vocab as u64) as i32);
                }
                s.history = prompt.clone();
            }
            (s.id, s.turns, prompt)
        } else {
            let sid = next_session;
            next_session += 1;
            let mut prompt = Vec::new();
            if spec.materialize_tokens {
                prompt = (0..prompt_len).map(|_| rng.below(spec.vocab as u64) as i32).collect();
            }
            sessions.push(Session { id: sid, history: prompt.clone(), turns: 0 });
            if sessions.len() > 256 {
                sessions.remove(0);
            }
            (sid, 0, prompt)
        };

        let prompt_tokens = if spec.materialize_tokens { prompt.len().max(prompt_len) } else { prompt_len };
        out.push(Request {
            id,
            arrival_us: t,
            prompt_tokens,
            output_tokens: output_len,
            prompt,
            session,
            turn,
        });
    }
    out
}

/// Zipf-skewed expert-activation sampler (EPLB stress; §1 "imbalanced
/// expert activations").
pub struct ExpertActivation {
    rng: Rng,
    n_experts: usize,
    alpha: f64,
    perm: Vec<usize>,
}

impl ExpertActivation {
    pub fn new(seed: u64, n_experts: usize, alpha: f64) -> Self {
        let mut rng = Rng::new(seed);
        let mut perm: Vec<usize> = (0..n_experts).collect();
        rng.shuffle(&mut perm);
        ExpertActivation { rng, n_experts, alpha, perm }
    }

    /// Draw top-k distinct experts for one token.
    pub fn sample_topk(&mut self, k: usize) -> Vec<usize> {
        let mut picked = Vec::with_capacity(k);
        let mut guard = 0;
        while picked.len() < k && guard < 100 {
            let e = self.perm[self.rng.zipf(self.n_experts as u64, self.alpha) as usize];
            if !picked.contains(&e) {
                picked.push(e);
            }
            guard += 1;
        }
        while picked.len() < k {
            let e = self.rng.below(self.n_experts as u64) as usize;
            if !picked.contains(&e) {
                picked.push(e);
            }
        }
        picked
    }

    /// Per-expert token counts for a batch — the EPLB input.
    pub fn batch_histogram(&mut self, tokens: usize, k: usize) -> Vec<u64> {
        let mut h = vec![0u64; self.n_experts];
        for _ in 0..tokens {
            for e in self.sample_topk(k) {
                h[e] += 1;
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic() {
        let spec = WorkloadSpec::paper_default(9);
        let a = generate(&spec, 100);
        let b = generate(&spec, 100);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_us, y.arrival_us);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
        }
    }

    #[test]
    fn arrivals_monotone_lengths_bounded() {
        let spec = WorkloadSpec::paper_default(1);
        let trace = generate(&spec, 500);
        let mut last = 0.0;
        for r in &trace {
            assert!(r.arrival_us >= last);
            last = r.arrival_us;
            assert!((spec.min_prompt..=spec.max_prompt).contains(&r.prompt_tokens));
            assert!((spec.min_output..=spec.max_output).contains(&r.output_tokens));
        }
    }

    #[test]
    fn multi_turn_sessions_share_prefixes() {
        let mut spec = WorkloadSpec::e2e_small(3, 128, 2048);
        spec.multi_turn_prob = 1.0;
        let trace = generate(&spec, 50);
        let with_turns: Vec<_> = trace.iter().filter(|r| r.turn > 0).collect();
        assert!(!with_turns.is_empty());
        // a turn>0 request's prompt must extend some earlier prompt
        for r in with_turns.iter().take(5) {
            let parent = trace
                .iter()
                .filter(|p| p.session == r.session && p.turn + 1 == r.turn)
                .next_back();
            if let Some(p) = parent {
                if !p.prompt.is_empty() {
                    assert!(r.prompt.starts_with(&p.prompt[..p.prompt.len().min(8)]));
                }
            }
        }
    }

    #[test]
    fn expert_skew_is_skewed() {
        let mut ea = ExpertActivation::new(5, 256, 1.1);
        let h = ea.batch_histogram(4000, 8);
        let total: u64 = h.iter().sum();
        assert_eq!(total, 4000 * 8);
        let mut sorted = h.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let top16: u64 = sorted[..16].iter().sum();
        // top 6% of experts should carry far more than 6% of load
        assert!(top16 as f64 / total as f64 > 0.25, "top16 share {}", top16 as f64 / total as f64);
    }

    #[test]
    fn topk_distinct() {
        let mut ea = ExpertActivation::new(6, 64, 1.2);
        for _ in 0..200 {
            let picks = ea.sample_topk(8);
            let mut sorted = picks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8);
        }
    }
}
