//! Workload generation: the dynamic, heterogeneous request patterns the
//! paper's §1/§4.1 motivate — bursty Poisson arrivals, log-normal
//! prompt/output lengths, multi-turn sessions with shared prefixes, and
//! Zipf-skewed expert activation.
//!
//! On top of the stationary [`WorkloadSpec`] sits [`ScenarioSpec`]: named,
//! time-varying scenarios (piecewise phases + sinusoidal rate modulation +
//! mixed SLO tiers) that exercise the elastic PDC autoscaler — `diurnal`,
//! `burst_storm`, `long_context_drift` and `mixed_slo` presets.

use crate::util::Rng;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time, µs from run start.
    pub arrival_us: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    /// Tokens of the prompt (only generated when prefix caching matters;
    /// empty means "synthetic lengths only").
    pub prompt: Vec<i32>,
    /// Session this request belongs to (multi-turn reuse).
    pub session: u64,
    /// Turn index within the session.
    pub turn: u32,
    /// SLO tier (0 = the deployment's base SLO; higher tiers index
    /// `ServingConfig::tier_slos`). Mixed-SLO scenarios thread this through
    /// the batcher's per-tier concurrency caps.
    pub slo_tier: usize,
    /// Prefix tokens importable from another supernode's pool over the
    /// RDMA plane. Set only by the fleet admission router
    /// ([`crate::fleet::FleetRouter`]) when a session re-homes across
    /// pods; the trace generator always emits 0.
    pub xpod_import_tokens: usize,
}

/// Workload shape parameters.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub seed: u64,
    /// Mean request inter-arrival time, µs. Poisson process.
    pub mean_interarrival_us: f64,
    /// Burstiness: probability that an arrival spawns a burst…
    pub burst_prob: f64,
    /// …of this mean size (geometric).
    pub burst_mean: f64,
    /// Log-normal prompt length: ln-space mean and sigma.
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    pub min_prompt: usize,
    pub max_prompt: usize,
    /// Log-normal output length parameters.
    pub output_mu: f64,
    pub output_sigma: f64,
    pub min_output: usize,
    pub max_output: usize,
    /// Fraction of requests continuing an existing session (prefix reuse).
    pub multi_turn_prob: f64,
    /// Session-popularity skew (Zipf alpha; 0 = uniform). Hot sessions are
    /// what make cache-affinity routing hotspot (§4.1).
    pub session_skew: f64,
    /// Whether to materialize prompt token ids (needed for cache tests).
    pub materialize_tokens: bool,
    /// Vocabulary for materialized tokens.
    pub vocab: usize,
    /// Piecewise time-varying arrival rate: `(start_us, mean_interarrival_us)`
    /// breakpoints in ascending `start_us` order. From each breakpoint on,
    /// the process uses that mean inter-arrival time; before the first
    /// breakpoint (and when empty) `mean_interarrival_us` applies.
    pub rate_points: Vec<(f64, f64)>,
}

impl WorkloadSpec {
    /// A 4K-ish prompt / 256-output mix at moderate load (Table 4/5 style).
    pub fn paper_default(seed: u64) -> Self {
        WorkloadSpec {
            seed,
            mean_interarrival_us: 2_000.0,
            burst_prob: 0.05,
            burst_mean: 6.0,
            prompt_mu: (4096.0f64).ln() - 0.18,
            prompt_sigma: 0.6,
            min_prompt: 64,
            max_prompt: 16384,
            output_mu: (256.0f64).ln() - 0.08,
            output_sigma: 0.4,
            min_output: 16,
            max_output: 2048,
            multi_turn_prob: 0.45,
            session_skew: 0.0,
            materialize_tokens: false,
            vocab: 2048,
            rate_points: Vec::new(),
        }
    }

    /// Small trace sized for the real-model E2E examples.
    pub fn e2e_small(seed: u64, prefill_seq: usize, vocab: usize) -> Self {
        WorkloadSpec {
            seed,
            mean_interarrival_us: 30_000.0,
            burst_prob: 0.15,
            burst_mean: 3.0,
            prompt_mu: (prefill_seq as f64 * 0.5).ln(),
            prompt_sigma: 0.4,
            min_prompt: 8,
            max_prompt: prefill_seq,
            output_mu: (24.0f64).ln(),
            output_sigma: 0.3,
            min_output: 4,
            max_output: 48,
            multi_turn_prob: 0.5,
            session_skew: 0.0,
            materialize_tokens: true,
            vocab,
            rate_points: Vec::new(),
        }
    }
}

/// Session state for multi-turn prefix construction.
struct Session {
    id: u64,
    history: Vec<i32>,
    turns: u32,
}

/// Generator knobs that may vary over virtual time (piecewise phases,
/// sinusoidal modulation). For a stationary [`WorkloadSpec`] they equal the
/// spec's own fields at every `t`.
#[derive(Debug, Clone, Copy)]
struct ShapeAt {
    mean_interarrival_us: f64,
    prompt_mu: f64,
    prompt_sigma: f64,
    output_mu: f64,
    output_sigma: f64,
}

impl ShapeAt {
    fn of_spec(spec: &WorkloadSpec, t: f64) -> ShapeAt {
        // piecewise arrival rate: latest breakpoint at or before t wins
        let mean_interarrival_us = spec
            .rate_points
            .iter()
            .rev()
            .find(|&&(start, _)| start <= t)
            .map(|&(_, ia)| ia)
            .unwrap_or(spec.mean_interarrival_us);
        ShapeAt {
            mean_interarrival_us,
            prompt_mu: spec.prompt_mu,
            prompt_sigma: spec.prompt_sigma,
            output_mu: spec.output_mu,
            output_sigma: spec.output_sigma,
        }
    }
}

/// Generate a trace of `n` requests.
pub fn generate(spec: &WorkloadSpec, n: usize) -> Vec<Request> {
    generate_impl(spec, None, n)
}

/// Generate a trace from a time-varying [`ScenarioSpec`].
pub fn generate_scenario(scenario: &ScenarioSpec, n: usize) -> Vec<Request> {
    generate_impl(&scenario.base, Some(scenario), n)
}

fn generate_impl(spec: &WorkloadSpec, scenario: Option<&ScenarioSpec>, n: usize) -> Vec<Request> {
    let mut rng = Rng::new(spec.seed);
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    let mut sessions: Vec<Session> = Vec::new();
    let mut next_session = 0u64;
    let mut burst_left = 0usize;

    for id in 0..n as u64 {
        let shape_here = match scenario {
            Some(sc) => sc.shape_at(spec, t),
            None => ShapeAt::of_spec(spec, t),
        };
        if burst_left > 0 {
            burst_left -= 1;
            t += rng.exponential(shape_here.mean_interarrival_us * 0.05);
        } else {
            t += rng.exponential(shape_here.mean_interarrival_us);
            if rng.f64() < spec.burst_prob {
                burst_left = (rng.exponential(spec.burst_mean) as usize).clamp(1, 64);
            }
        }
        // lengths follow the arrival's own phase
        let shape = match scenario {
            Some(sc) => sc.shape_at(spec, t),
            None => ShapeAt::of_spec(spec, t),
        };

        let prompt_len = (rng.lognormal(shape.prompt_mu, shape.prompt_sigma) as usize)
            .clamp(spec.min_prompt, spec.max_prompt);
        let output_len = (rng.lognormal(shape.output_mu, shape.output_sigma) as usize)
            .clamp(spec.min_output, spec.max_output);

        let slo_tier = match scenario {
            Some(sc) if !sc.tier_mix.is_empty() => sc.sample_tier(&mut rng),
            _ => 0,
        };

        // multi-turn: continue a random session, prefix = its history
        let reuse = !sessions.is_empty() && rng.f64() < spec.multi_turn_prob;
        let (session, turn, prompt) = if reuse {
            let idx = if spec.session_skew > 0.0 {
                rng.zipf(sessions.len() as u64, spec.session_skew) as usize
            } else {
                rng.below(sessions.len() as u64) as usize
            };
            let s = &mut sessions[idx];
            s.turns += 1;
            let mut prompt = Vec::new();
            if spec.materialize_tokens {
                prompt = s.history.clone();
                let new_part = prompt_len.saturating_sub(prompt.len()).max(1);
                for _ in 0..new_part {
                    prompt.push(rng.below(spec.vocab as u64) as i32);
                }
                s.history = prompt.clone();
            }
            (s.id, s.turns, prompt)
        } else {
            let sid = next_session;
            next_session += 1;
            let mut prompt = Vec::new();
            if spec.materialize_tokens {
                prompt = (0..prompt_len).map(|_| rng.below(spec.vocab as u64) as i32).collect();
            }
            sessions.push(Session { id: sid, history: prompt.clone(), turns: 0 });
            if sessions.len() > 256 {
                sessions.remove(0);
            }
            (sid, 0, prompt)
        };

        let prompt_tokens = if spec.materialize_tokens { prompt.len().max(prompt_len) } else { prompt_len };
        out.push(Request {
            id,
            arrival_us: t,
            prompt_tokens,
            output_tokens: output_len,
            prompt,
            session,
            turn,
            slo_tier,
            xpod_import_tokens: 0,
        });
    }
    out
}

// ---------------------------------------------------------------------------
// Scenario layer: named time-varying workloads for the elastic PDC loop
// ---------------------------------------------------------------------------

/// One piecewise scenario phase: from `start_us` on, these arrival/length
/// parameters apply (until the next phase starts).
#[derive(Debug, Clone, Copy)]
pub struct ScenarioPhase {
    pub start_us: f64,
    pub mean_interarrival_us: f64,
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    pub output_mu: f64,
    pub output_sigma: f64,
}

/// Sinusoidal arrival-rate modulation: the instantaneous rate is scaled by
/// `1 + amplitude * sin(2π t / period_us)` (the "diurnal" wave).
#[derive(Debug, Clone, Copy)]
pub struct RateWave {
    pub period_us: f64,
    /// In [0, 1): peak-to-mean rate swing.
    pub amplitude: f64,
}

/// A named, time-varying scenario layered on a base [`WorkloadSpec`]:
/// piecewise phases override arrival rate and length distributions,
/// an optional [`RateWave`] modulates the arrival rate sinusoidally,
/// `tier_mix` assigns per-request SLO tiers for mixed-SLO serving, and
/// `fault_profile` (the `chaos_*` presets) names the fault classes a
/// chaos run injects alongside the workload.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: &'static str,
    pub base: WorkloadSpec,
    /// Phases in ascending `start_us` order; before the first phase the
    /// base spec's parameters apply.
    pub phases: Vec<ScenarioPhase>,
    pub wave: Option<RateWave>,
    /// `(tier, weight)` sampled independently per request; empty = tier 0.
    pub tier_mix: Vec<(usize, f64)>,
    /// SLOs for tiers 1.. as `(tpot_ms, ttft_ms)`, aligned with
    /// `ServingConfig::tier_slos` (tier 0 stays the deployment's base SLO).
    pub tier_slos_ms: Vec<(f64, f64)>,
    /// Chaos: the fault classes this scenario injects (None = healthy).
    /// Trace generation ignores it; the sim layer builds a seeded
    /// [`crate::faults::FaultPlan`] from it.
    pub fault_profile: Option<crate::faults::FaultProfile>,
    /// Correlated chaos: clustered domain incidents instead of (or on top
    /// of) the independent `fault_profile`. Trace generation ignores it;
    /// the sim layer expands it against the deployment's
    /// [`crate::domains::FailureDomainMap`].
    pub correlated: Option<crate::domains::CorrelatedProfile>,
}

/// ln-space mean so the log-normal's *mean* lands on `target`.
fn ln_mean(target: f64, sigma: f64) -> f64 {
    target.ln() - sigma * sigma / 2.0
}

impl ScenarioSpec {
    /// All preset names accepted by [`ScenarioSpec::by_name`].
    pub const PRESETS: [&'static str; 11] = [
        "diurnal",
        "burst_storm",
        "long_context_drift",
        "mixed_slo",
        "memory_bound_decode",
        "session_chat",
        "agentic_loop",
        "chaos_crashes",
        "chaos_degraded",
        "correlated_rack_loss",
        "fleet_diurnal",
    ];

    pub fn by_name(name: &str, seed: u64) -> Option<ScenarioSpec> {
        match name {
            "diurnal" => Some(Self::diurnal(seed)),
            "burst_storm" => Some(Self::burst_storm(seed)),
            "long_context_drift" => Some(Self::long_context_drift(seed)),
            "mixed_slo" => Some(Self::mixed_slo(seed)),
            "memory_bound_decode" => Some(Self::memory_bound_decode(seed)),
            "session_chat" => Some(Self::session_chat(seed)),
            "agentic_loop" => Some(Self::agentic_loop(seed)),
            "chaos_crashes" => Some(Self::chaos_crashes(seed)),
            "chaos_degraded" => Some(Self::chaos_degraded(seed)),
            "correlated_rack_loss" => Some(Self::correlated_rack_loss(seed)),
            "fleet_diurnal" => Some(Self::fleet_diurnal(seed)),
            _ => None,
        }
    }

    /// Day/night cycle (paper §4.1 "dynamic real-world workloads"): a
    /// sinusoidal arrival wave over a 24 s virtual "day" whose first half
    /// is interactive/RAG traffic (long prompts, short answers) and whose
    /// second half is batch-generation traffic (short prompts, long
    /// outputs). The prompt:output demand ratio flips by ~3 orders of
    /// magnitude at the phase boundary — the workload that motivates
    /// independent prefill/decode scaling.
    pub fn diurnal(seed: u64) -> ScenarioSpec {
        let mut base = WorkloadSpec::paper_default(seed);
        base.mean_interarrival_us = 10_000.0;
        base.burst_prob = 0.02;
        base.multi_turn_prob = 0.1;
        base.min_prompt = 64;
        base.max_prompt = 16_384;
        base.min_output = 8;
        base.max_output = 2_048;
        let period = 24e6;
        ScenarioSpec {
            name: "diurnal",
            base,
            phases: vec![
                // "day": RAG — long prompts, terse answers
                ScenarioPhase {
                    start_us: 0.0,
                    mean_interarrival_us: 10_000.0,
                    prompt_mu: ln_mean(6144.0, 0.25),
                    prompt_sigma: 0.25,
                    output_mu: ln_mean(32.0, 0.3),
                    output_sigma: 0.3,
                },
                // "night": batch generation — short prompts, long outputs
                ScenarioPhase {
                    start_us: period / 2.0,
                    mean_interarrival_us: 10_000.0,
                    prompt_mu: ln_mean(256.0, 0.3),
                    prompt_sigma: 0.3,
                    output_mu: ln_mean(1024.0, 0.25),
                    output_sigma: 0.25,
                },
            ],
            wave: Some(RateWave { period_us: period, amplitude: 0.25 }),
            tier_mix: Vec::new(),
            tier_slos_ms: Vec::new(),
            fault_profile: None,
            correlated: None,
        }
    }

    /// Heavy-tailed burst storms: a moderate base rate punctuated by large
    /// geometric bursts — the load-balance stress that §4.1's stateless
    /// P2P routing argument targets.
    pub fn burst_storm(seed: u64) -> ScenarioSpec {
        let mut base = WorkloadSpec::paper_default(seed);
        base.mean_interarrival_us = 6_000.0;
        base.burst_prob = 0.30;
        base.burst_mean = 20.0;
        ScenarioSpec {
            name: "burst_storm",
            base,
            phases: Vec::new(),
            wave: None,
            tier_mix: Vec::new(),
            tier_slos_ms: Vec::new(),
            fault_profile: None,
            correlated: None,
        }
    }

    /// Prompt-length distribution drifting upward mid-run (1 K → 12 K):
    /// models a tenant mix shifting toward long-context workloads, which
    /// must pull NPUs into the prefill pool over time.
    pub fn long_context_drift(seed: u64) -> ScenarioSpec {
        let mut base = WorkloadSpec::paper_default(seed);
        base.mean_interarrival_us = 8_000.0;
        base.multi_turn_prob = 0.2;
        let phase = |start_us: f64, prompt: f64| ScenarioPhase {
            start_us,
            mean_interarrival_us: 8_000.0,
            prompt_mu: ln_mean(prompt, 0.3),
            prompt_sigma: 0.3,
            output_mu: ln_mean(128.0, 0.3),
            output_sigma: 0.3,
        };
        ScenarioSpec {
            name: "long_context_drift",
            base,
            phases: vec![
                phase(0.0, 1024.0),
                phase(5e6, 2048.0),
                phase(10e6, 8192.0),
                phase(15e6, 12_288.0),
            ],
            wave: None,
            tier_mix: Vec::new(),
            tier_slos_ms: Vec::new(),
            fault_profile: None,
            correlated: None,
        }
    }

    /// Mixed SLO tiers (Table 5's 15 ms vs 50 ms TPOT targets) arriving
    /// interleaved: 70% standard-tier, 30% tight-tier traffic. The batcher
    /// enforces a separate SLO-derived concurrency cap per tier.
    pub fn mixed_slo(seed: u64) -> ScenarioSpec {
        let mut base = WorkloadSpec::paper_default(seed);
        base.mean_interarrival_us = 4_000.0;
        ScenarioSpec {
            name: "mixed_slo",
            base,
            phases: Vec::new(),
            wave: None,
            tier_mix: vec![(0, 0.7), (1, 0.3)],
            tier_slos_ms: vec![(15.0, 1_500.0)],
            fault_profile: None,
            correlated: None,
        }
    }

    /// The §6.2.1 attention-offload regime: long-context, decode-heavy
    /// traffic at a steady (low-variance) arrival rate. Prompts average
    /// ~4 K tokens and outputs ~1.5 K, so decode slots attend over long KV
    /// at deep batches — the memory-bound FA-core regime — while the
    /// prompt token rate leaves the prefill pool with idle NPU-seconds.
    /// This is where offloading a fraction of decode attention onto donor
    /// prefill instances beats (or avoids) a full resplit. Pair it with a
    /// decode-pressured slice (`--decode-npus 32` on the default config)
    /// to saturate the decode batch.
    pub fn memory_bound_decode(seed: u64) -> ScenarioSpec {
        let mut base = WorkloadSpec::paper_default(seed);
        base.mean_interarrival_us = 25_000.0; // steady ~40 req/s
        base.burst_prob = 0.0; // low arrival variance
        base.burst_mean = 1.0;
        base.multi_turn_prob = 0.0; // every prompt fully computed
        base.prompt_mu = ln_mean(4096.0, 0.2);
        base.prompt_sigma = 0.2;
        base.min_prompt = 1024;
        base.max_prompt = 12_288;
        base.output_mu = ln_mean(1536.0, 0.25);
        base.output_sigma = 0.25;
        base.min_output = 256;
        base.max_output = 4096;
        ScenarioSpec {
            name: "memory_bound_decode",
            base,
            phases: Vec::new(),
            wave: None,
            tier_mix: Vec::new(),
            tier_slos_ms: Vec::new(),
            fault_profile: None,
            correlated: None,
        }
    }

    /// Multi-turn chat sessions (the Fig 23 production story): most
    /// arrivals continue an existing conversation whose prompt is the
    /// full history plus a short new user turn, so follow-up turns share
    /// a long, growing prefix with their predecessors. Tokens are
    /// materialized — the serving loop's [`crate::cache::ContextCache`]
    /// probes real chain-hashed block keys — and session popularity is
    /// Zipf-skewed, which is what makes cache-affinity routing hotspot.
    pub fn session_chat(seed: u64) -> ScenarioSpec {
        let mut base = WorkloadSpec::paper_default(seed);
        base.mean_interarrival_us = 5_000.0;
        base.burst_prob = 0.05;
        base.burst_mean = 4.0;
        base.prompt_mu = ln_mean(1536.0, 0.5);
        base.prompt_sigma = 0.5;
        base.min_prompt = 128;
        base.max_prompt = 8_192;
        base.output_mu = ln_mean(192.0, 0.35);
        base.output_sigma = 0.35;
        base.min_output = 16;
        base.max_output = 768;
        base.multi_turn_prob = 0.75;
        base.session_skew = 1.1;
        base.materialize_tokens = true;
        ScenarioSpec {
            name: "session_chat",
            base,
            phases: Vec::new(),
            wave: None,
            tier_mix: Vec::new(),
            tier_slos_ms: Vec::new(),
            fault_profile: None,
            correlated: None,
        }
    }

    /// Fleet-scale diurnal chat: the `session_chat` session structure
    /// (materialized tokens, Zipf-hot sessions, long shared prefixes)
    /// under a sinusoidal diurnal arrival wave. Region skew emerges from
    /// the session skew itself — hot sessions concentrate on their home
    /// pod under affinity routing — and the wave's peak (t = period/4) is
    /// where the fleet maintenance drain of one pod lands, forcing
    /// sessions to re-home across supernodes at the worst moment.
    pub fn fleet_diurnal(seed: u64) -> ScenarioSpec {
        let mut sc = Self::session_chat(seed);
        sc.name = "fleet_diurnal";
        sc.wave = Some(RateWave { period_us: 24e6, amplitude: 0.45 });
        sc
    }

    /// Agentic tool loops: interleaved think/act turns against a shared
    /// scratchpad. Nearly every arrival continues a session, the freshly
    /// appended tool result is small relative to the accumulated context,
    /// and outputs are terse tool calls — so the prefix-cached share of
    /// each prefill is even higher than `session_chat` and decode turns
    /// are short and latency-critical.
    pub fn agentic_loop(seed: u64) -> ScenarioSpec {
        let mut base = WorkloadSpec::paper_default(seed);
        base.mean_interarrival_us = 3_500.0;
        base.burst_prob = 0.10;
        base.burst_mean = 5.0;
        base.prompt_mu = ln_mean(768.0, 0.45);
        base.prompt_sigma = 0.45;
        base.min_prompt = 64;
        base.max_prompt = 8_192;
        base.output_mu = ln_mean(64.0, 0.3);
        base.output_sigma = 0.3;
        base.min_output = 8;
        base.max_output = 256;
        base.multi_turn_prob = 0.9;
        base.session_skew = 0.9;
        base.materialize_tokens = true;
        ScenarioSpec {
            name: "agentic_loop",
            base,
            phases: Vec::new(),
            wave: None,
            tier_mix: Vec::new(),
            tier_slos_ms: Vec::new(),
            fault_profile: None,
            correlated: None,
        }
    }

    /// The acceptance chaos scenario: a `diurnal` day with decode/prefill
    /// instance crashes and memory-pool server failures injected mid-run.
    /// Run it recovery-on vs recovery-off to measure what the §4.4.1
    /// resilience story is worth in goodput.
    pub fn chaos_crashes(seed: u64) -> ScenarioSpec {
        let mut sc = Self::diurnal(seed);
        sc.name = "chaos_crashes";
        sc.fault_profile = Some(crate::faults::FaultProfile::crashes(24e6));
        sc
    }

    /// Gray-failure chaos: `burst_storm` traffic over a fabric with
    /// degradation windows and straggling decode instances — nothing
    /// crashes, everything slows.
    pub fn chaos_degraded(seed: u64) -> ScenarioSpec {
        let mut sc = Self::burst_storm(seed);
        sc.name = "chaos_degraded";
        sc.fault_profile = Some(crate::faults::FaultProfile::degraded(8e6));
        sc
    }

    /// Correlated chaos: the diurnal day hit by clustered *domain*
    /// incidents — rack/PSU losses that fell every member component at
    /// once (plus a UB sub-plane brown-out) — instead of independent
    /// crashes. The scenario the domain-aware
    /// [`crate::domains::ResilienceController`] (donor spreading, mass
    /// recall, decode backfill) is measured on, against the independent
    /// recovery baseline and `--no-recovery`.
    pub fn correlated_rack_loss(seed: u64) -> ScenarioSpec {
        let mut sc = Self::diurnal(seed);
        sc.name = "correlated_rack_loss";
        sc.correlated = Some(crate::domains::CorrelatedProfile::rack_loss(24e6));
        sc
    }

    /// The extra-tier SLOs as config objects, ready to assign to
    /// `ServingConfig::tier_slos` (single source of the tier encoding).
    pub fn tier_slo_configs(&self) -> Vec<crate::config::SloConfig> {
        self.tier_slos_ms
            .iter()
            .map(|&(tpot_ms, ttft_ms)| crate::config::SloConfig { tpot_ms, ttft_ms })
            .collect()
    }

    /// Effective generator shape at virtual time `t`.
    fn shape_at(&self, spec: &WorkloadSpec, t: f64) -> ShapeAt {
        let mut s = ShapeAt::of_spec(spec, t);
        if let Some(p) = self.phases.iter().rev().find(|p| p.start_us <= t) {
            s.mean_interarrival_us = p.mean_interarrival_us;
            s.prompt_mu = p.prompt_mu;
            s.prompt_sigma = p.prompt_sigma;
            s.output_mu = p.output_mu;
            s.output_sigma = p.output_sigma;
        }
        if let Some(w) = self.wave {
            let mult = 1.0 + w.amplitude * (2.0 * std::f64::consts::PI * t / w.period_us).sin();
            s.mean_interarrival_us /= mult.max(0.05);
        }
        s
    }

    /// Draw a request's SLO tier from `tier_mix`.
    fn sample_tier(&self, rng: &mut Rng) -> usize {
        let total: f64 = self.tier_mix.iter().map(|&(_, w)| w).sum();
        let mut u = rng.f64() * total;
        for &(tier, w) in &self.tier_mix {
            if u < w {
                return tier;
            }
            u -= w;
        }
        self.tier_mix.last().map(|&(t, _)| t).unwrap_or(0)
    }
}

/// Zipf-skewed expert-activation sampler (EPLB stress; §1 "imbalanced
/// expert activations").
pub struct ExpertActivation {
    rng: Rng,
    n_experts: usize,
    alpha: f64,
    perm: Vec<usize>,
}

impl ExpertActivation {
    pub fn new(seed: u64, n_experts: usize, alpha: f64) -> Self {
        let mut rng = Rng::new(seed);
        let mut perm: Vec<usize> = (0..n_experts).collect();
        rng.shuffle(&mut perm);
        ExpertActivation { rng, n_experts, alpha, perm }
    }

    /// Draw top-k distinct experts for one token.
    pub fn sample_topk(&mut self, k: usize) -> Vec<usize> {
        let mut picked = Vec::with_capacity(k);
        let mut guard = 0;
        while picked.len() < k && guard < 100 {
            let e = self.perm[self.rng.zipf(self.n_experts as u64, self.alpha) as usize];
            if !picked.contains(&e) {
                picked.push(e);
            }
            guard += 1;
        }
        while picked.len() < k {
            let e = self.rng.below(self.n_experts as u64) as usize;
            if !picked.contains(&e) {
                picked.push(e);
            }
        }
        picked
    }

    /// Per-expert token counts for a batch — the EPLB input.
    pub fn batch_histogram(&mut self, tokens: usize, k: usize) -> Vec<u64> {
        let mut h = vec![0u64; self.n_experts];
        for _ in 0..tokens {
            for e in self.sample_topk(k) {
                h[e] += 1;
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic() {
        let spec = WorkloadSpec::paper_default(9);
        let a = generate(&spec, 100);
        let b = generate(&spec, 100);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_us, y.arrival_us);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
        }
    }

    #[test]
    fn arrivals_monotone_lengths_bounded() {
        let spec = WorkloadSpec::paper_default(1);
        let trace = generate(&spec, 500);
        let mut last = 0.0;
        for r in &trace {
            assert!(r.arrival_us >= last);
            last = r.arrival_us;
            assert!((spec.min_prompt..=spec.max_prompt).contains(&r.prompt_tokens));
            assert!((spec.min_output..=spec.max_output).contains(&r.output_tokens));
        }
    }

    #[test]
    fn multi_turn_sessions_share_prefixes() {
        let mut spec = WorkloadSpec::e2e_small(3, 128, 2048);
        spec.multi_turn_prob = 1.0;
        let trace = generate(&spec, 50);
        let with_turns: Vec<_> = trace.iter().filter(|r| r.turn > 0).collect();
        assert!(!with_turns.is_empty());
        // a turn>0 request's prompt must extend some earlier prompt
        for r in with_turns.iter().take(5) {
            let parent = trace
                .iter()
                .filter(|p| p.session == r.session && p.turn + 1 == r.turn)
                .next_back();
            if let Some(p) = parent {
                if !p.prompt.is_empty() {
                    assert!(r.prompt.starts_with(&p.prompt[..p.prompt.len().min(8)]));
                }
            }
        }
    }

    #[test]
    fn scenario_traces_are_deterministic() {
        for name in ScenarioSpec::PRESETS {
            let sc = ScenarioSpec::by_name(name, 13).unwrap();
            let a = generate_scenario(&sc, 200);
            let b = generate_scenario(&sc, 200);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.arrival_us, y.arrival_us, "{name}");
                assert_eq!(x.prompt_tokens, y.prompt_tokens, "{name}");
                assert_eq!(x.slo_tier, y.slo_tier, "{name}");
            }
        }
    }

    #[test]
    fn diurnal_flips_prompt_output_mix() {
        let sc = ScenarioSpec::diurnal(3);
        let trace = generate_scenario(&sc, 2400);
        let half = 12e6;
        let (day, night): (Vec<_>, Vec<_>) =
            trace.iter().partition(|r| r.arrival_us < half);
        assert!(day.len() > 200 && night.len() > 200, "{} / {}", day.len(), night.len());
        let mean = |xs: &[&Request], f: fn(&Request) -> usize| {
            xs.iter().map(|r| f(r) as f64).sum::<f64>() / xs.len() as f64
        };
        let day_prompt = mean(&day, |r| r.prompt_tokens);
        let day_output = mean(&day, |r| r.output_tokens);
        let night_prompt = mean(&night, |r| r.prompt_tokens);
        let night_output = mean(&night, |r| r.output_tokens);
        assert!(day_prompt > 8.0 * day_output, "day {day_prompt} vs {day_output}");
        assert!(night_output > 2.0 * night_prompt, "night {night_prompt} vs {night_output}");
    }

    #[test]
    fn piecewise_rate_points_shift_density() {
        let mut spec = WorkloadSpec::paper_default(4);
        spec.burst_prob = 0.0;
        spec.mean_interarrival_us = 1_000.0;
        spec.rate_points = vec![(0.0, 1_000.0), (1e6, 20_000.0)];
        let trace = generate(&spec, 1200);
        let early = trace.iter().filter(|r| r.arrival_us < 1e6).count();
        let late_window =
            trace.iter().filter(|r| (1e6..2e6).contains(&r.arrival_us)).count();
        // ~1000 arrivals expected in the first second, ~50 in the next
        assert!(early > 700, "early {early}");
        assert!(late_window < early / 4, "late {late_window} vs early {early}");
    }

    #[test]
    fn mixed_slo_interleaves_tiers() {
        let sc = ScenarioSpec::mixed_slo(5);
        let trace = generate_scenario(&sc, 1000);
        let tight = trace.iter().filter(|r| r.slo_tier == 1).count();
        assert!((150..=450).contains(&tight), "tight-tier count {tight}");
        // interleaved, not phase-separated: tight tier present in each third
        for w in 0..3 {
            let lo = w * 333;
            let in_window = trace[lo..lo + 333].iter().filter(|r| r.slo_tier == 1).count();
            assert!(in_window > 20, "window {w}: {in_window}");
        }
        assert_eq!(sc.tier_slos_ms.len(), 1);
    }

    #[test]
    fn long_context_drift_grows_prompts() {
        let sc = ScenarioSpec::long_context_drift(6);
        let trace = generate_scenario(&sc, 2000);
        let mean_in = |lo: f64, hi: f64| {
            let xs: Vec<_> =
                trace.iter().filter(|r| (lo..hi).contains(&r.arrival_us)).collect();
            assert!(!xs.is_empty());
            xs.iter().map(|r| r.prompt_tokens as f64).sum::<f64>() / xs.len() as f64
        };
        let first = mean_in(0.0, 5e6);
        let last = mean_in(15e6, f64::MAX);
        assert!(last > 4.0 * first, "drift {first} -> {last}");
    }

    #[test]
    fn chaos_presets_carry_fault_profiles() {
        let c = ScenarioSpec::by_name("chaos_crashes", 3).unwrap();
        let p = c.fault_profile.expect("chaos preset must carry a fault profile");
        assert!(p.decode_crashes + p.prefill_crashes + p.pool_failures > 0);
        let d = ScenarioSpec::by_name("chaos_degraded", 3).unwrap();
        let dp = d.fault_profile.unwrap();
        assert_eq!(dp.decode_crashes + dp.prefill_crashes + dp.pool_failures, 0);
        assert!(dp.link_degrades > 0 && dp.stragglers > 0);
        // the correlated preset carries a clustered profile, not an
        // independent one
        let cr = ScenarioSpec::by_name("correlated_rack_loss", 3).unwrap();
        assert!(cr.fault_profile.is_none());
        let cp = cr.correlated.expect("correlated preset must carry a profile");
        assert!(cp.rack_incidents > 0);
        // healthy presets carry none
        for name in [
            "diurnal",
            "burst_storm",
            "long_context_drift",
            "mixed_slo",
            "memory_bound_decode",
            "session_chat",
            "agentic_loop",
            "fleet_diurnal",
        ] {
            let sc = ScenarioSpec::by_name(name, 3).unwrap();
            assert!(sc.fault_profile.is_none(), "{name}");
            assert!(sc.correlated.is_none(), "{name}");
        }
        // the chaos workload is its base preset — faults ride alongside,
        // they don't change the trace
        let a = generate_scenario(&ScenarioSpec::diurnal(3), 100);
        let b = generate_scenario(&c, 100);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_us, y.arrival_us);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
        }
    }

    #[test]
    fn fleet_diurnal_is_session_chat_under_a_wave() {
        let sc = ScenarioSpec::by_name("fleet_diurnal", 9).unwrap();
        assert!(sc.base.materialize_tokens, "fleet routing needs real prefixes");
        let wave = sc.wave.expect("fleet preset must carry a diurnal wave");
        assert_eq!(wave.period_us, 24e6);
        let trace = generate_scenario(&sc, 4000);
        // sessions dominate (re-homing has something to move)…
        let turns = trace.iter().filter(|r| r.turn > 0).count();
        assert!(turns * 2 > trace.len(), "only {turns} follow-up turns");
        // …and the wave shows: arrivals around the peak (t ≈ period/4)
        // clearly outnumber arrivals around the trough (t ≈ 3·period/4)
        let count_in = |lo: f64, hi: f64| {
            trace.iter().filter(|r| (lo..hi).contains(&r.arrival_us)).count()
        };
        let peak = count_in(4e6, 8e6);
        let trough = count_in(16e6, 20e6);
        assert!(
            peak as f64 > 1.5 * trough.max(1) as f64,
            "peak {peak} vs trough {trough}"
        );
        // the generator itself never marks cross-pod imports
        assert!(trace.iter().all(|r| r.xpod_import_tokens == 0));
    }

    #[test]
    fn memory_bound_decode_is_long_context_decode_heavy_low_variance() {
        let sc = ScenarioSpec::memory_bound_decode(8);
        let trace = generate_scenario(&sc, 1000);
        let mean = |f: fn(&Request) -> usize| {
            trace.iter().map(|r| f(r) as f64).sum::<f64>() / trace.len() as f64
        };
        let mean_prompt = mean(|r| r.prompt_tokens);
        let mean_output = mean(|r| r.output_tokens);
        // long context: prompts land around 4 K
        assert!((3000.0..6000.0).contains(&mean_prompt), "prompt {mean_prompt}");
        // decode-heavy: outputs around 1.5 K — decode KV grows past 5 K
        assert!((1100.0..2200.0).contains(&mean_output), "output {mean_output}");
        // low arrival variance: no bursts, so the squared coefficient of
        // variation of inter-arrivals stays near the Poisson baseline (1)
        let gaps: Vec<f64> = trace.windows(2).map(|w| w[1].arrival_us - w[0].arrival_us).collect();
        let mu = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mu) * (g - mu)).sum::<f64>() / gaps.len() as f64;
        let cv2 = var / (mu * mu);
        assert!(cv2 < 1.5, "bursty arrivals in a low-variance preset: cv² {cv2}");
        // the burst-storm preset is far burstier by the same measure
        let storm = generate_scenario(&ScenarioSpec::burst_storm(8), 1000);
        let sgaps: Vec<f64> =
            storm.windows(2).map(|w| w[1].arrival_us - w[0].arrival_us).collect();
        let smu = sgaps.iter().sum::<f64>() / sgaps.len() as f64;
        let svar = sgaps.iter().map(|g| (g - smu) * (g - smu)).sum::<f64>() / sgaps.len() as f64;
        assert!(svar / (smu * smu) > cv2, "burst_storm must be burstier");
    }

    #[test]
    fn session_presets_materialize_growing_prefixes() {
        for name in ["session_chat", "agentic_loop"] {
            let sc = ScenarioSpec::by_name(name, 11).unwrap();
            assert!(sc.base.materialize_tokens, "{name} must materialize tokens");
            let trace = generate_scenario(&sc, 800);
            // every request carries real token ids
            assert!(trace.iter().all(|r| !r.prompt.is_empty()), "{name}: empty prompt");
            // the workload is dominated by follow-up turns
            let turns = trace.iter().filter(|r| r.turn > 0).count();
            assert!(turns * 2 > trace.len(), "{name}: only {turns} follow-up turns");
            // a follow-up turn's prompt extends its parent's prompt exactly
            let mut checked = 0;
            for r in trace.iter().filter(|r| r.turn > 0) {
                let parent =
                    trace.iter().rfind(|p| p.session == r.session && p.turn + 1 == r.turn);
                if let Some(p) = parent {
                    assert!(
                        r.prompt.len() > p.prompt.len() && r.prompt.starts_with(&p.prompt),
                        "{name}: turn {} does not extend its parent prefix",
                        r.turn
                    );
                    checked += 1;
                }
            }
            assert!(checked > 50, "{name}: too few parent/child pairs ({checked})");
        }
        // the agentic loop is turnier and terser than chat
        let chat = generate_scenario(&ScenarioSpec::session_chat(11), 800);
        let agent = generate_scenario(&ScenarioSpec::agentic_loop(11), 800);
        let frac = |t: &[Request]| {
            t.iter().filter(|r| r.turn > 0).count() as f64 / t.len() as f64
        };
        assert!(frac(&agent) > frac(&chat), "agentic_loop must be turnier");
        let mean_out = |t: &[Request]| {
            t.iter().map(|r| r.output_tokens as f64).sum::<f64>() / t.len() as f64
        };
        assert!(mean_out(&agent) < mean_out(&chat), "agentic turns must be terse");
    }

    #[test]
    fn expert_skew_is_skewed() {
        let mut ea = ExpertActivation::new(5, 256, 1.1);
        let h = ea.batch_histogram(4000, 8);
        let total: u64 = h.iter().sum();
        assert_eq!(total, 4000 * 8);
        let mut sorted = h.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let top16: u64 = sorted[..16].iter().sum();
        // top 6% of experts should carry far more than 6% of load
        assert!(top16 as f64 / total as f64 > 0.25, "top16 share {}", top16 as f64 / total as f64);
    }

    #[test]
    fn topk_distinct() {
        let mut ea = ExpertActivation::new(6, 64, 1.2);
        for _ in 0..200 {
            let picks = ea.sample_topk(8);
            let mut sorted = picks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8);
        }
    }
}
