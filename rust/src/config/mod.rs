//! Configuration system: hardware constants of the CloudMatrix384 supernode
//! and the Ascend 910C (calibrated from the paper, §3.2–§3.3 and Tables
//! 1/7/8/9/10), DeepSeek-R1 model dimensions used by the simulator, serving
//! deployment presets (§5.1), and a minimal TOML loader for user overrides.

mod hw;
mod serving;
pub mod toml;

pub use hw::{Ascend910cDie, CloudMatrixTopo, DeepSeekDims, NetPlaneParams, UB_PLANES};
pub use serving::{DeploymentPreset, PlacementObjective, ServingConfig, SloConfig};

use crate::util::Result;
use std::path::Path;

/// Root config: hardware + model + serving.
#[derive(Debug, Clone)]
pub struct Config {
    pub die: Ascend910cDie,
    pub topo: CloudMatrixTopo,
    pub model: DeepSeekDims,
    pub serving: ServingConfig,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            die: Ascend910cDie::default(),
            topo: CloudMatrixTopo::default(),
            model: DeepSeekDims::deepseek_r1(),
            serving: ServingConfig::paper_default(),
        }
    }
}

impl Config {
    /// Load overrides from a TOML file on top of defaults.
    ///
    /// Recognized tables: `[die]`, `[topo]`, `[model]`, `[serving]`,
    /// `[serving.slo]` with keys matching the struct fields.
    pub fn from_toml_file(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<Config> {
        let doc = toml::parse(text)?;
        let mut cfg = Config::default();

        if let Some(t) = doc.table("die") {
            t.set_f64("bf16_tflops", &mut cfg.die.bf16_tflops);
            t.set_f64("int8_tops", &mut cfg.die.int8_tops);
            t.set_f64("hbm_gbps", &mut cfg.die.hbm_gbps);
            t.set_usize("aic_cores", &mut cfg.die.aic_cores);
            t.set_usize("aiv_cores", &mut cfg.die.aiv_cores);
            t.set_f64("ub_gbps", &mut cfg.die.ub_gbps);
            t.set_f64("rdma_gbps", &mut cfg.die.rdma_gbps);
            t.set_f64("sdma_startup_us", &mut cfg.die.sdma_startup_us);
            t.set_f64("aiv_direct_startup_us", &mut cfg.die.aiv_direct_startup_us);
        }
        if let Some(t) = doc.table("topo") {
            t.set_usize("nodes", &mut cfg.topo.nodes);
            t.set_usize("npus_per_node", &mut cfg.topo.npus_per_node);
            t.set_usize("cpus_per_node", &mut cfg.topo.cpus_per_node);
            t.set_usize("dies_per_npu", &mut cfg.topo.dies_per_npu);
            t.set_usize("l2_switches_per_plane", &mut cfg.topo.l2_switches_per_plane);
            t.set_usize("ports_per_l2_chip", &mut cfg.topo.ports_per_l2_chip);
        }
        if let Some(t) = doc.table("model") {
            t.set_usize("d_model", &mut cfg.model.d_model);
            t.set_usize("n_layers", &mut cfg.model.n_layers);
            t.set_usize("n_dense_layers", &mut cfg.model.n_dense_layers);
            t.set_usize("n_heads", &mut cfg.model.n_heads);
            t.set_usize("n_routed_experts", &mut cfg.model.n_routed_experts);
            t.set_usize("top_k", &mut cfg.model.top_k);
            t.set_usize("d_expert", &mut cfg.model.d_expert);
            t.set_usize("d_c", &mut cfg.model.d_c);
            t.set_usize("d_rope", &mut cfg.model.d_rope);
        }
        if let Some(t) = doc.table("serving") {
            t.set_usize("prefill_instances", &mut cfg.serving.prefill_instances);
            t.set_usize("npus_per_prefill", &mut cfg.serving.npus_per_prefill);
            t.set_usize("decode_npus", &mut cfg.serving.decode_npus);
            t.set_usize("decode_batch_per_die", &mut cfg.serving.decode_batch_per_die);
            t.set_bool("microbatch", &mut cfg.serving.microbatch);
            t.set_bool("mtp", &mut cfg.serving.mtp);
            t.set_f64("mtp_acceptance", &mut cfg.serving.mtp_acceptance);
            let mut placement = cfg.serving.placement.name().to_string();
            t.set_string("placement", &mut placement);
            match PlacementObjective::by_name(&placement) {
                Some(obj) => cfg.serving.placement = obj,
                None => crate::bail!(
                    "unknown serving.placement `{placement}` \
                     (packed | spread_racks | spread_planes)"
                ),
            }
        }
        if let Some(t) = doc.table("serving.slo") {
            t.set_f64("tpot_ms", &mut cfg.serving.slo.tpot_ms);
            t.set_f64("ttft_ms", &mut cfg.serving.slo.ttft_ms);
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = Config::default();
        assert_eq!(c.topo.total_npus(), 384);
        assert_eq!(c.topo.total_dies(), 768);
        assert_eq!(c.topo.total_cpus(), 192);
        assert!((c.die.bf16_tflops - 376.0).abs() < 1e-9);
        assert_eq!(c.model.n_routed_experts, 256);
    }

    #[test]
    fn toml_overrides() {
        let cfg = Config::from_toml(
            "[die]\nbf16_tflops = 400.0\n[serving]\nmtp = false\ndecode_npus = 32\n\
             placement = \"spread_racks\"\n[serving.slo]\ntpot_ms = 15.0\n",
        )
        .unwrap();
        assert!((cfg.die.bf16_tflops - 400.0).abs() < 1e-9);
        assert!(!cfg.serving.mtp);
        assert_eq!(cfg.serving.decode_npus, 32);
        assert_eq!(cfg.serving.placement, PlacementObjective::SpreadRacks);
        assert!((cfg.serving.slo.tpot_ms - 15.0).abs() < 1e-9);
        // untouched defaults survive
        assert_eq!(cfg.topo.nodes, 48);
        // an unknown objective is a load-time error, not a silent default
        assert!(Config::from_toml("[serving]\nplacement = \"striped\"\n").is_err());
    }
}
