//! Minimal TOML subset parser (the `toml` crate is not vendored; DESIGN.md
//! §1). Supports: `[table]` / `[dotted.table]` headers, `key = value` with
//! string / integer / float / boolean values, comments, blank lines. This
//! covers every config file this project reads; arrays and inline tables are
//! intentionally rejected with a clear error.

use std::collections::BTreeMap;

use crate::bail;
use crate::util::error::Result;

/// A scalar TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
}

/// A parsed document: table name → key → value. Root keys go in "".
#[derive(Debug, Default)]
pub struct TomlDoc {
    tables: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

/// One table view with typed setters used by config loading.
pub struct TableView<'a> {
    map: &'a BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn table(&self, name: &str) -> Option<TableView<'_>> {
        self.tables.get(name).map(|map| TableView { map })
    }

    pub fn tables(&self) -> impl Iterator<Item = &String> {
        self.tables.keys()
    }
}

impl<'a> TableView<'a> {
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.map.get(key)
    }

    /// Overwrite `dst` if the key is present and numeric.
    pub fn set_f64(&self, key: &str, dst: &mut f64) {
        if let Some(TomlValue::Num(n)) = self.map.get(key) {
            *dst = *n;
        }
    }

    pub fn set_usize(&self, key: &str, dst: &mut usize) {
        if let Some(TomlValue::Num(n)) = self.map.get(key) {
            *dst = *n as usize;
        }
    }

    pub fn set_bool(&self, key: &str, dst: &mut bool) {
        if let Some(TomlValue::Bool(b)) = self.map.get(key) {
            *dst = *b;
        }
    }

    pub fn set_string(&self, key: &str, dst: &mut String) {
        if let Some(TomlValue::Str(s)) = self.map.get(key) {
            *dst = s.clone();
        }
    }
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut current = String::new();
    doc.tables.entry(current.clone()).or_default();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated table header", lineno + 1);
            };
            let name = name.trim();
            if name.is_empty() || name.starts_with('[') {
                bail!("line {}: array-of-tables not supported", lineno + 1);
            }
            current = name.to_string();
            doc.tables.entry(current.clone()).or_default();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected `key = value`", lineno + 1);
        };
        let key = line[..eq].trim().to_string();
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| crate::anyhow!("line {}: {e}", lineno + 1))?;
        doc.tables.get_mut(&current).unwrap().insert(key, val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: don't strip '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let Some(inner) = inner.strip_suffix('"') else {
            bail!("unterminated string");
        };
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if s.starts_with('[') || s.starts_with('{') {
        bail!("arrays / inline tables not supported by this TOML subset");
    }
    let cleaned: String = s.chars().filter(|c| *c != '_').collect();
    match cleaned.parse::<f64>() {
        Ok(n) => Ok(TomlValue::Num(n)),
        Err(_) => bail!("cannot parse value `{s}`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tables_and_scalars() {
        let doc = parse(
            "top = 1\n\
             [a]\n\
             x = 1.5   # comment\n\
             s = \"hi # there\"\n\
             flag = true\n\
             [a.b]\n\
             y = 1_000\n",
        )
        .unwrap();
        assert_eq!(doc.table("").unwrap().get("top"), Some(&TomlValue::Num(1.0)));
        let a = doc.table("a").unwrap();
        assert_eq!(a.get("x"), Some(&TomlValue::Num(1.5)));
        assert_eq!(a.get("s"), Some(&TomlValue::Str("hi # there".into())));
        assert_eq!(a.get("flag"), Some(&TomlValue::Bool(true)));
        assert_eq!(doc.table("a.b").unwrap().get("y"), Some(&TomlValue::Num(1000.0)));
    }

    #[test]
    fn rejects_arrays() {
        assert!(parse("x = [1, 2]").is_err());
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("just a line").is_err());
        assert!(parse("[unterminated").is_err());
    }

    #[test]
    fn setters() {
        let doc = parse("[t]\na = 2\nb = true\nc = \"s\"\n").unwrap();
        let t = doc.table("t").unwrap();
        let mut f = 0.0;
        let mut u = 0usize;
        let mut b = false;
        let mut s = String::new();
        t.set_f64("a", &mut f);
        t.set_usize("a", &mut u);
        t.set_bool("b", &mut b);
        t.set_string("c", &mut s);
        assert_eq!((f, u, b, s.as_str()), (2.0, 2, true, "s"));
    }
}
