//! Serving deployment configuration (paper §4.1 / §5.1).

/// Deployment-layout objective for the placement planner
/// ([`crate::domains::PlacementPlanner`]): how prefill groups, decode
/// instances, and memory-pool servers are laid out over the supernode's
/// racks and UB sub-planes before the first request arrives.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementObjective {
    /// Maximal UB locality: components take contiguous NPU runs in
    /// physical order (the calibrated §5.1 layout and the default).
    #[default]
    Packed,
    /// Rack anti-affinity: component home nodes interleave across racks so
    /// no rack's loss fells more components than under `Packed` — blast
    /// radius bounded at a (marginal, modeled) cross-rack locality cost.
    SpreadRacks,
    /// `SpreadRacks` plus UB-plane striping: within each rack, nodes are
    /// visited in home-plane order so an instance's nodes (and the
    /// component home planes) additionally spread across the 7 sub-planes.
    SpreadPlanes,
}

impl PlacementObjective {
    /// Parse a CLI/TOML name (`packed`, `spread_racks`, `spread_planes`).
    pub fn by_name(name: &str) -> Option<PlacementObjective> {
        match name {
            "packed" => Some(PlacementObjective::Packed),
            "spread_racks" => Some(PlacementObjective::SpreadRacks),
            "spread_planes" => Some(PlacementObjective::SpreadPlanes),
            _ => None,
        }
    }

    /// The canonical name accepted by [`PlacementObjective::by_name`].
    pub fn name(&self) -> &'static str {
        match self {
            PlacementObjective::Packed => "packed",
            PlacementObjective::SpreadRacks => "spread_racks",
            PlacementObjective::SpreadPlanes => "spread_planes",
        }
    }
}

/// Latency service-level objectives (paper Table 5).
#[derive(Debug, Clone, Copy)]
pub struct SloConfig {
    /// Time-per-output-token target, ms.
    pub tpot_ms: f64,
    /// Time-to-first-token target, ms.
    pub ttft_ms: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig { tpot_ms: 50.0, ttft_ms: 3000.0 }
    }
}

/// Named deployment presets from the paper's evaluation (§5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeploymentPreset {
    /// §5.1: 6 prefill instances x 16 NPUs (EP32) + 1 decode instance x
    /// 160 NPUs (EP320), 256-NPU slice of a CloudMatrix384.
    Paper256,
    /// Whole-supernode variant: 8 prefill instances + 1 decode EP320.
    Full384,
    /// Small test deployment for unit/integration tests.
    Tiny,
}

/// Serving-system configuration: the PDC deployment shape plus feature
/// toggles for every ablation in §5.4.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Number of prefill instances.
    pub prefill_instances: usize,
    /// NPUs per prefill instance (16 → 32 dies → EP32).
    pub npus_per_prefill: usize,
    /// NPUs in the decode instance (160 → 320 dies → EP320).
    pub decode_npus: usize,
    /// Max decode batch per die (96 in Table 4).
    pub decode_batch_per_die: usize,
    /// Experts per prefill rank: 1 shared + 8 router + 1 redundant (§5.1).
    pub prefill_experts_per_rank: usize,
    /// Redundant router-expert replicas for EPLB (decode: 32).
    pub decode_redundant_experts: usize,
    /// Microbatch-based pipelining (§4.2.3 / §4.3.2; Figs 20–21 ablate).
    pub microbatch: bool,
    /// Multi-token prediction (§4.2.4; Fig 22 ablates).
    pub mtp: bool,
    /// MTP speculative-token acceptance rate (paper assumes 0.70).
    pub mtp_acceptance: f64,
    /// Staged hybrid parallelism for prefill MLA (§4.3.1; pure DP if false).
    pub hybrid_parallelism: bool,
    /// Use AIV-direct (vs SDMA) for dispatch/combine (§4.2.1 Opt.1).
    pub aiv_direct: bool,
    /// Early (pre-send) INT8 quantization of dispatch payloads (Opt.2).
    pub early_quant: bool,
    /// Context caching via EMS (§4.4.2; Fig 23 ablates).
    pub context_caching: bool,
    /// Route cache accesses over UB (true) or fall back to VPC (Fig 23).
    pub cache_over_ub: bool,
    /// Deployment-layout objective the placement planner lays the PDC
    /// roles out under ([`crate::domains::PlacementPlanner`]).
    pub placement: PlacementObjective,
    /// Latency SLOs (tier 0).
    pub slo: SloConfig,
    /// Additional SLO tiers for mixed-SLO serving (Table 5 mechanism):
    /// tier `i+1` of a request maps to `tier_slos[i]`. Each tier gets its
    /// own SLO-derived decode concurrency cap in the batcher. Empty by
    /// default (single-tier deployment).
    pub tier_slos: Vec<SloConfig>,
}

impl ServingConfig {
    /// The paper's §5.1 evaluation deployment.
    pub fn paper_default() -> Self {
        ServingConfig {
            prefill_instances: 6,
            npus_per_prefill: 16,
            decode_npus: 160,
            decode_batch_per_die: 96,
            prefill_experts_per_rank: 10,
            decode_redundant_experts: 32,
            microbatch: true,
            mtp: true,
            mtp_acceptance: 0.70,
            hybrid_parallelism: true,
            aiv_direct: true,
            early_quant: true,
            context_caching: true,
            cache_over_ub: true,
            placement: PlacementObjective::Packed,
            slo: SloConfig::default(),
            tier_slos: Vec::new(),
        }
    }

    pub fn preset(p: DeploymentPreset) -> Self {
        match p {
            DeploymentPreset::Paper256 => Self::paper_default(),
            DeploymentPreset::Full384 => ServingConfig {
                prefill_instances: 8,
                ..Self::paper_default()
            },
            DeploymentPreset::Tiny => ServingConfig {
                prefill_instances: 1,
                npus_per_prefill: 2,
                decode_npus: 4,
                decode_batch_per_die: 8,
                ..Self::paper_default()
            },
        }
    }

    /// Dies in the decode instance (EP degree for MoE layers).
    pub fn decode_ep_degree(&self) -> usize {
        self.decode_npus * 2
    }

    /// Dies per prefill instance (EP degree inside one instance).
    pub fn prefill_ep_degree(&self) -> usize {
        self.npus_per_prefill * 2
    }

    /// Total NPUs provisioned.
    pub fn total_npus(&self) -> usize {
        self.prefill_instances * self.npus_per_prefill + self.decode_npus
    }

    /// Number of SLO tiers (>= 1; tier 0 is the base SLO).
    pub fn n_tiers(&self) -> usize {
        1 + self.tier_slos.len()
    }

    /// The SLO for a request tier; out-of-range tiers fall back to tier 0.
    pub fn slo_for_tier(&self, tier: usize) -> SloConfig {
        if tier == 0 {
            self.slo
        } else {
            self.tier_slos.get(tier - 1).copied().unwrap_or(self.slo)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_deployment_shape() {
        let s = ServingConfig::paper_default();
        assert_eq!(s.decode_ep_degree(), 320);
        assert_eq!(s.prefill_ep_degree(), 32);
        assert_eq!(s.total_npus(), 6 * 16 + 160); // 256-NPU slice (§5.1)
        assert_eq!(s.placement, PlacementObjective::Packed);
    }

    #[test]
    fn placement_objective_names_round_trip() {
        for obj in [
            PlacementObjective::Packed,
            PlacementObjective::SpreadRacks,
            PlacementObjective::SpreadPlanes,
        ] {
            assert_eq!(PlacementObjective::by_name(obj.name()), Some(obj));
        }
        assert_eq!(PlacementObjective::by_name("striped"), None);
        assert_eq!(PlacementObjective::default(), PlacementObjective::Packed);
    }
}
