//! Hardware constants, calibrated from the paper.
//!
//! Sources: §3.3.1 (Ascend 910C chip), §3.3.2 (node), §3.3.3 (UB switch
//! system), Table 1 (plane bandwidth/latency), §5.5 (operator utilizations).
//! Note the paper's abstract quotes 1,054 INT8 TFLOPS/NPU but Tables 3–4 use
//! 1,504 TFLOPS/NPU (752/die); we follow the tables (DESIGN.md §5).

/// One Ascend 910C *die* (each NPU packages two).
#[derive(Debug, Clone)]
pub struct Ascend910cDie {
    /// Dense BF16/FP16 throughput, TFLOPS (§3.3.1: ~376/die).
    pub bf16_tflops: f64,
    /// INT8 throughput, TOPS (752/die — Tables 3/4/10).
    pub int8_tops: f64,
    /// HBM bandwidth per die, GB/s (1.6 TB/s).
    pub hbm_gbps: f64,
    /// HBM capacity per die, GB (64 GB).
    pub hbm_gb: f64,
    /// AI cube (matrix) cores per die (§3.3.1: 24).
    pub aic_cores: usize,
    /// AI vector cores per die (§3.3.1: 48).
    pub aiv_cores: usize,
    /// UB plane unidirectional bandwidth per die, GB/s (196).
    pub ub_gbps: f64,
    /// RDMA plane unidirectional bandwidth per die, GB/s (200 Gbps = 25).
    pub rdma_gbps: f64,
    /// Cross-die on-package bandwidth, GB/s per direction (270).
    pub cross_die_gbps: f64,
    /// SDMA transfer-engine startup latency, µs (§4.2.1: the bottleneck
    /// AIV-direct removes; calibrated so Table 7 shapes reproduce).
    pub sdma_startup_us: f64,
    /// AIV-direct write startup latency, µs.
    pub aiv_direct_startup_us: f64,
    /// Per-operator NPU launch overhead, µs (§4.2.2 bottleneck (1)).
    pub op_launch_us: f64,
    /// Graph (compute-graph) dispatch startup, ms 0.6–0.8 (§4.2.4).
    pub graph_dispatch_us: f64,
    /// GEMM sustained efficiency vs peak (Table 10: 0.77–0.83).
    pub gemm_efficiency: f64,
    /// MLA compute-bound utilization (Table 8: 0.654).
    pub mla_compute_util: f64,
    /// MLA memory-bound bandwidth utilization (Table 9: 0.841).
    pub mla_memory_util: f64,
}

impl Default for Ascend910cDie {
    fn default() -> Self {
        Ascend910cDie {
            bf16_tflops: 376.0,
            int8_tops: 752.0,
            hbm_gbps: 1600.0,
            hbm_gb: 64.0,
            aic_cores: 24,
            aiv_cores: 48,
            ub_gbps: 196.0,
            rdma_gbps: 25.0,
            cross_die_gbps: 270.0,
            sdma_startup_us: 25.0,
            aiv_direct_startup_us: 4.0,
            op_launch_us: 2.0,
            graph_dispatch_us: 700.0,
            gemm_efficiency: 0.80,
            mla_compute_util: 0.654,
            mla_memory_util: 0.841,
        }
    }
}

impl Ascend910cDie {
    /// Effective INT8 ops/µs at sustained GEMM efficiency.
    pub fn int8_ops_per_us(&self) -> f64 {
        self.int8_tops * 1e12 * self.gemm_efficiency / 1e6
    }

    /// Effective BF16 flops/µs at sustained GEMM efficiency.
    pub fn bf16_flops_per_us(&self) -> f64 {
        self.bf16_tflops * 1e12 * self.gemm_efficiency / 1e6
    }

    /// Effective HBM bytes/µs at MLA memory utilization.
    pub fn hbm_bytes_per_us(&self) -> f64 {
        self.hbm_gbps * 1e9 * self.mla_memory_util / 1e6
    }
}

/// Number of UB switch sub-planes (§3.3.3: 7, one per on-board L1 chip).
pub const UB_PLANES: usize = 7;

/// CloudMatrix384 supernode topology (§3.2–§3.3).
#[derive(Debug, Clone)]
pub struct CloudMatrixTopo {
    /// Compute nodes in the supernode (48).
    pub nodes: usize,
    /// Ascend 910C NPUs per node (8).
    pub npus_per_node: usize,
    /// Kunpeng CPUs per node (4).
    pub cpus_per_node: usize,
    /// Dies per NPU package (2).
    pub dies_per_npu: usize,
    /// Compute nodes per rack: the PSU/power failure domain (§2.2-style
    /// correlated incidents take out a whole rack's NPU groups at once).
    pub nodes_per_rack: usize,
    /// L1 UB switch chips on each node board (7).
    pub l1_switches_per_node: usize,
    /// L2 switch chips per sub-plane (16).
    pub l2_switches_per_plane: usize,
    /// Ports per L2 switch chip (48 × 28 GB/s).
    pub ports_per_l2_chip: usize,
    /// Port bandwidth, GB/s (28).
    pub port_gbps: f64,
    /// L1 uplink capacity per switch chip, GB/s (448).
    pub l1_uplink_gbps: f64,
    /// CPU socket UB bandwidth, GB/s (~160).
    pub cpu_ub_gbps: f64,
    /// DRAM per CPU socket usable for pooling, GB.
    pub dram_per_cpu_gb: f64,
    /// VPC (Qingtian) per-node bandwidth, GB/s (400 Gbps = 50).
    pub vpc_gbps_per_node: f64,
}

impl Default for CloudMatrixTopo {
    fn default() -> Self {
        CloudMatrixTopo {
            nodes: 48,
            npus_per_node: 8,
            cpus_per_node: 4,
            dies_per_npu: 2,
            nodes_per_rack: 4,
            l1_switches_per_node: UB_PLANES,
            l2_switches_per_plane: 16,
            ports_per_l2_chip: 48,
            port_gbps: 28.0,
            l1_uplink_gbps: 448.0,
            cpu_ub_gbps: 160.0,
            dram_per_cpu_gb: 768.0,
            vpc_gbps_per_node: 50.0,
        }
    }
}

impl CloudMatrixTopo {
    pub fn total_npus(&self) -> usize {
        self.nodes * self.npus_per_node
    }

    pub fn total_dies(&self) -> usize {
        self.total_npus() * self.dies_per_npu
    }

    pub fn total_cpus(&self) -> usize {
        self.nodes * self.cpus_per_node
    }

    /// Rack (PSU failure-domain) count.
    pub fn racks(&self) -> usize {
        self.nodes.div_ceil(self.nodes_per_rack.max(1))
    }

    /// Rack holding a compute node.
    pub fn rack_of_node(&self, node: usize) -> usize {
        node / self.nodes_per_rack.max(1)
    }

    /// Pooled DRAM across the supernode, GB (the disaggregated memory pool).
    pub fn pooled_dram_gb(&self) -> f64 {
        self.total_cpus() as f64 * self.dram_per_cpu_gb
    }

    /// A scaled-down topology with the same ratios (tests / fast sims).
    pub fn scaled(nodes: usize) -> Self {
        CloudMatrixTopo { nodes, ..Default::default() }
    }
}

/// Network-plane cost-model parameters (α + size/β), from Table 1.
#[derive(Debug, Clone, Copy)]
pub struct NetPlaneParams {
    /// Startup/propagation latency, µs (512-byte latency from Table 1).
    pub base_latency_us: f64,
    /// Achievable unidirectional bandwidth, GB/s.
    pub bandwidth_gbps: f64,
}

impl NetPlaneParams {
    /// Transfer time for `bytes`, µs.
    pub fn transfer_us(&self, bytes: u64) -> f64 {
        self.base_latency_us + bytes as f64 / (self.bandwidth_gbps * 1e3)
    }
}

/// DeepSeek-R1 dimensions (§3.5.1) — drives the simulator's FLOP/byte math.
#[derive(Debug, Clone)]
pub struct DeepSeekDims {
    pub d_model: usize,
    pub n_layers: usize,
    /// Leading dense (non-MoE) layers.
    pub n_dense_layers: usize,
    pub n_heads: usize,
    /// Latent (compressed KV) dim.
    pub d_c: usize,
    /// Shared RoPE key dim.
    pub d_rope: usize,
    /// Per-head no-PE q/k dim.
    pub d_nope: usize,
    /// Per-head value dim.
    pub d_v: usize,
    /// Query LoRA rank (DeepSeek-V3: 1536).
    pub q_lora_rank: usize,
    pub n_routed_experts: usize,
    pub n_shared_experts: usize,
    pub top_k: usize,
    /// Routed expert hidden dim.
    pub d_expert: usize,
    /// Dense/shared FFN hidden dim.
    pub d_ffn: usize,
    pub vocab_size: usize,
}

impl DeepSeekDims {
    /// DeepSeek-R1 / V3 (671B total, 37B active).
    pub fn deepseek_r1() -> Self {
        DeepSeekDims {
            d_model: 7168,
            n_layers: 61,
            n_dense_layers: 3,
            n_heads: 128,
            d_c: 512,
            d_rope: 64,
            d_nope: 128,
            d_v: 128,
            q_lora_rank: 1536,
            n_routed_experts: 256,
            n_shared_experts: 1,
            top_k: 8,
            d_expert: 2048,
            d_ffn: 18432,
            vocab_size: 129280,
        }
    }

    /// Hidden-state bytes per token (BF16) — the dispatch payload before
    /// early quantization (§4.2.1: 7168 dims → 14 KB BF16, 7.5 KB INT8).
    pub fn token_bf16_bytes(&self) -> u64 {
        (self.d_model * 2) as u64
    }

    /// INT8 dispatch message bytes/token: 7 KB payload + 512 B scale slot.
    pub fn token_int8_msg_bytes(&self) -> u64 {
        self.d_model as u64 + 512
    }

    /// Combine message bytes/token (unquantized BF16 + alignment).
    pub fn token_combine_msg_bytes(&self) -> u64 {
        self.d_model as u64 * 2
    }

    /// Latent KV-cache bytes per token per layer (BF16 latents + rope).
    pub fn kv_bytes_per_token_layer(&self) -> u64 {
        ((self.d_c + self.d_rope) * 2) as u64
    }

    /// Full KV-cache bytes per token across layers.
    pub fn kv_bytes_per_token(&self) -> u64 {
        self.kv_bytes_per_token_layer() * self.n_layers as u64
    }

    /// FLOPs for one token of decode attention+proj (absorbed MLA),
    /// per layer. 2·MAC convention.
    pub fn decode_attn_flops_per_token_layer(&self, kv_len: usize) -> f64 {
        let h = self.n_heads as f64;
        let (dc, dr, dn, dv) = (self.d_c as f64, self.d_rope as f64, self.d_nope as f64, self.d_v as f64);
        let d = self.d_model as f64;
        // q proj (via lora), kv down-proj, rope key
        let proj = 2.0 * d * (self.q_lora_rank as f64)
            + 2.0 * (self.q_lora_rank as f64) * h * (dn + dr)
            + 2.0 * d * (dc + dr);
        // absorption: q_abs = q_nope @ W_uk per head
        let absorb = 2.0 * h * dn * dc;
        // scores + weighted sum over kv_len latents
        let attn = 2.0 * h * (kv_len as f64) * (dc + dr) + 2.0 * h * (kv_len as f64) * dc;
        // output up-proj + o_proj
        let out = 2.0 * h * dc * dv + 2.0 * h * dv * d;
        proj + absorb + attn + out
    }

    /// FLOPs for one token of MoE FFN per layer (top-k + shared experts).
    pub fn moe_flops_per_token_layer(&self) -> f64 {
        let d = self.d_model as f64;
        let active = (self.top_k + self.n_shared_experts) as f64;
        // SwiGLU: 3 matmuls (gate, up, down)
        active * 3.0 * 2.0 * d * self.d_expert as f64
    }

    /// Total decode FLOPs per token across layers (attention + MoE).
    pub fn decode_flops_per_token(&self, kv_len: usize) -> f64 {
        let moe_layers = (self.n_layers - self.n_dense_layers) as f64;
        let dense_layers = self.n_dense_layers as f64;
        let attn: f64 = self.decode_attn_flops_per_token_layer(kv_len) * self.n_layers as f64;
        let dense = dense_layers * 3.0 * 2.0 * self.d_model as f64 * self.d_ffn as f64;
        let moe = moe_layers * self.moe_flops_per_token_layer();
        attn + dense + moe + 2.0 * self.d_model as f64 * self.vocab_size as f64
    }

    /// Prefill FLOPs per token (quadratic attention term at prompt_len).
    pub fn prefill_flops_per_token(&self, prompt_len: usize) -> f64 {
        // non-absorbed MHA: qk^T + av over the causal half
        let h = self.n_heads as f64;
        let dqk = (self.d_nope + self.d_rope) as f64;
        let dv = self.d_v as f64;
        let l = self.n_layers as f64;
        let causal = prompt_len as f64 / 2.0;
        let attn_quad = l * (2.0 * h * causal * dqk + 2.0 * h * causal * dv);
        self.decode_flops_per_token(0) + attn_quad
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn r1_sanity() {
        let m = DeepSeekDims::deepseek_r1();
        // dispatch message ≈ 7.5 KB (paper §4.2.1)
        assert_eq!(m.token_int8_msg_bytes(), 7168 + 512);
        // combine ≈ 14 KB
        assert_eq!(m.token_combine_msg_bytes(), 14336);
        // MLA cache per token should be ~93% smaller than naive MHA cache:
        let naive = (m.n_heads * (m.d_nope + m.d_v) * 2) as u64; // per layer
        let mla = m.kv_bytes_per_token_layer();
        let reduction = 1.0 - mla as f64 / naive as f64;
        assert!(reduction > 0.90, "MLA reduction {reduction}");
    }

    #[test]
    fn die_effective_rates() {
        let d = Ascend910cDie::default();
        assert!(d.int8_ops_per_us() > 0.0);
        assert!(d.hbm_bytes_per_us() > 1e6); // > 1 GB/ms
    }

    #[test]
    fn decode_flops_order_of_magnitude() {
        let m = DeepSeekDims::deepseek_r1();
        let f = m.decode_flops_per_token(4096);
        // ~37B active params → ~70-90 GFLOPs/token + attention reads
        assert!(f > 3e10 && f < 3e11, "decode flops {f}");
    }
}
