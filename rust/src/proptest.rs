//! Mini property-testing harness (proptest is not vendored — DESIGN.md §1).
//!
//! Seeded generators over [`crate::util::Rng`] + a `check` runner that, on
//! failure, retries with simple size-shrinking (halving generated sizes) and
//! reports the failing seed so the case is reproducible:
//!
//! ```no_run
//! use cm_infer::proptest::check;
//! check("sorted-after-sort", 200, |g| {
//!     let mut v = g.vec_u64(0..=1000, 0..=50);
//!     v.sort();
//!     v.windows(2).all(|w| w[0] <= w[1])
//! });
//! ```

use std::ops::RangeInclusive;

use crate::util::Rng;

/// Value generator handed to each property iteration.
pub struct Gen {
    rng: Rng,
    /// Size multiplier in (0, 1]; shrunk on failure retries.
    size: f64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), size: 1.0 }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Scaled length: at size 1.0 samples the full range.
    fn scaled_len(&mut self, range: &RangeInclusive<usize>) -> usize {
        let lo = *range.start();
        let hi = *range.end();
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        lo + self.rng.below(span as u64 + 1) as usize
    }

    pub fn u64(&mut self, range: RangeInclusive<u64>) -> u64 {
        self.rng.range(*range.start(), *range.end())
    }

    pub fn usize(&mut self, range: RangeInclusive<usize>) -> usize {
        self.rng.range(*range.start() as u64, *range.end() as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_u64(&mut self, each: RangeInclusive<u64>, len: RangeInclusive<usize>) -> Vec<u64> {
        let n = self.scaled_len(&len);
        (0..n).map(|_| self.u64(each.clone())).collect()
    }

    pub fn vec_usize(
        &mut self,
        each: RangeInclusive<usize>,
        len: RangeInclusive<usize>,
    ) -> Vec<usize> {
        let n = self.scaled_len(&len);
        (0..n).map(|_| self.usize(each.clone())).collect()
    }

    pub fn string(&mut self, len: RangeInclusive<usize>) -> String {
        let n = self.scaled_len(&len);
        (0..n)
            .map(|_| char::from(b'a' + self.rng.below(26) as u8))
            .collect()
    }
}

/// Run `prop` over `cases` seeded generations; panics with the failing seed.
///
/// On first failure the case is re-run at smaller generator sizes to report
/// the smallest size that still fails (shrinking-lite).
pub fn check<F: Fn(&mut Gen) -> bool>(name: &str, cases: u64, prop: F) {
    let base = env_seed();
    for i in 0..cases {
        let seed = base.wrapping_add(i).wrapping_mul(0x9E3779B97F4A7C15) ^ i;
        let mut g = Gen::new(seed);
        if !prop(&mut g) {
            // shrink: retry same seed at reduced sizes, find smallest failing
            let mut smallest = 1.0;
            for k in 1..=6 {
                let size = 1.0 / (1 << k) as f64;
                let mut g = Gen::new(seed);
                g.size = size;
                if !prop(&mut g) {
                    smallest = size;
                } else {
                    break;
                }
            }
            panic!(
                "property `{name}` failed (case {i}, seed {seed:#x}, \
                 smallest failing size {smallest}). Re-run with \
                 CM_PROPTEST_SEED={base} to reproduce."
            );
        }
    }
}

fn env_seed() -> u64 {
    std::env::var("CM_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs() {
        check("sort-idempotent", 50, |g| {
            let mut v = g.vec_u64(0..=100, 0..=40);
            v.sort();
            let w = {
                let mut w = v.clone();
                w.sort();
                w
            };
            v == w
        });
    }

    #[test]
    #[should_panic(expected = "property `always-false` failed")]
    fn failing_property_panics_with_seed() {
        check("always-false", 5, |_| false);
    }

    #[test]
    fn generators_respect_ranges() {
        check("ranges", 100, |g| {
            let x = g.u64(5..=10);
            let v = g.vec_usize(1..=3, 2..=4);
            (5..=10).contains(&x)
                && (v.is_empty() || (2..=4).contains(&v.len()))
                && v.iter().all(|e| (1..=3).contains(e))
        });
    }
}
