//! # cm-infer — CloudMatrix-Infer reproduction
//!
//! A three-layer reproduction of *"Serving Large Language Models on Huawei
//! CloudMatrix384"* (Zuo et al., 2025):
//!
//! * **Layer 3 (this crate)** — the paper's serving system: a peer-to-peer
//!   prefill–decode–caching (PDC) disaggregated coordinator, large-scale
//!   expert parallelism (LEP), microbatch pipelines, MTP speculative
//!   decoding, a UB-driven disaggregated memory pool with context/model
//!   caching, and a calibrated discrete simulation of the CloudMatrix384
//!   supernode substrate (topology, network planes, Ascend 910C dies).
//! * **Layer 2/1 (python/, build-time only)** — a JAX MoE transformer with
//!   MLA attention and Pallas kernels, AOT-lowered to HLO text artifacts
//!   that [`runtime`] loads and executes through PJRT. Python never runs on
//!   the request path. (The PJRT path is gated behind the `pjrt` cargo
//!   feature; the default build substitutes an error-returning stub so the
//!   whole crate builds offline with zero external dependencies.)
//!
//! ## Elastic PDC
//!
//! The serving simulation implements the paper's §4.1 "Dynamic Adjustment"
//! end to end: [`coordinator::sim::ServeSim`] runs a *pool* of decode
//! instances behind a placement policy
//! ([`coordinator::sim::DecodePlacement`]), and — when
//! [`coordinator::sim::SimOptions::autoscale`] is set — wires the
//! [`coordinator::autoscale::Autoscaler`] into the event loop as a periodic
//! `ScaleEpoch`: windowed workload stats in, a `SplitPlan` out, enacted by
//! draining prefill instances into the decode pool (or the reverse) with a
//! modeled role-switch latency (the Table 2 model-cache warm switch).
//! Every move lands in the report's resplit log, alongside per-phase
//! NPU-seconds and per-tier SLO attainment
//! ([`metrics::ServingReport`]).
//!
//! Time-varying workloads come from the scenario layer
//! ([`workload::ScenarioSpec`]) with named presets:
//!
//! * `diurnal` — sinusoidal arrival wave; prompt-heavy "day" flips to
//!   output-heavy "night" (drives resplits in both directions),
//! * `burst_storm` — heavy-tailed arrival bursts,
//! * `long_context_drift` — the prompt-length distribution drifts 1 K→12 K
//!   mid-run,
//! * `mixed_slo` — interleaved 50 ms / 15 ms TPOT tiers, enforced by
//!   per-tier concurrency quotas in [`coordinator::batcher`],
//! * `memory_bound_decode` — long-context, decode-heavy, low-variance
//!   traffic: the §6.2.1 attention-offload regime,
//! * `session_chat` / `agentic_loop` — multi-turn sessions with
//!   materialized, growing prefixes: the context-caching + cache-affinity
//!   regime (see **Sessions** below).
//!
//! ## Elastic actions and §6.2.1 attention offloading
//!
//! Each `ScaleEpoch` now recommends one
//! [`coordinator::autoscale::ElasticAction`] — the unified elasticity
//! state machine:
//!
//! ```text
//!            ┌────────── Resplit(SplitPlan) ──────────┐
//!            │   (move NPU groups; Table 2 warm        │
//!            │    role-switch latency per group)       │
//!   no offload active ──────────────────────────────►──┘
//!        │         ▲
//!        │ Offload { frac, donors }                 Recall { reason }
//!        │   (decode memory-bound + measured           ▲
//!        │    prefill idle; instant, no moves)         │
//!        ▼         │                                   │
//!   offload active ┴──── donor crash → DonorFailure ───┤ (TPOT spike
//!                  ├──── pressure gone → PressureResolved (graceful)
//!                  └──── resplit enacted → Preempted   │ window)
//! ```
//!
//! While engaged, decode steps take the offloaded per-layer latency from
//! [`coordinator::autoscale::offload::model_offload`]; donor prefill
//! instances (a first-class [`coordinator::router::InstanceState`]) stay
//! admissible for prefill but pay the modeled HBM-bandwidth tax; and a
//! donor crash forces the decode side to pull the FA core back locally —
//! a transient TPOT degradation window, never a stall. The report logs
//! every transition ([`metrics::OffloadEvent`]) plus `donor_tax_us`,
//! `recall_spike_us`, and per-role busy-vs-assigned NPU-seconds.
//!
//! ## Chaos (fault injection + recovery orchestration)
//!
//! The [`faults`] subsystem turns the paper's §4.4.1 fault-resilience claim
//! into an executable experiment: a deterministic, seeded
//! [`faults::FaultPlan`] (instance/NPU crashes, memory-pool server
//! failures, UB/RDMA link-degradation windows, stragglers) is injected into
//! [`coordinator::sim::ServeSim`] as first-class events. Failures are
//! *detected* at heartbeat epochs; recovery orchestration then re-homes
//! stranded work (decode requests re-fetch surviving prompt KV from the
//! pool, or re-prefill when it was DRAM-only and lost), masks failed
//! instances out of the [`coordinator::router`], and warm-loads a
//! replacement NPU group at the Table 2 model-cache latency. The report
//! gains availability metrics (goodput vs. lost tokens, per-fault MTTR,
//! SLO attainment under faults) and the scenario layer gains
//! `chaos_crashes` / `chaos_degraded` presets, runnable from the
//! `simulate` CLI (`--scenario chaos_crashes [--no-recovery]`) and the
//! `slo_explorer` example.
//!
//! ## Failure domains (correlated chaos + domain-aware resilience)
//!
//! Production supernode availability is dominated by *correlated*
//! incidents, not independent crashes. The [`domains`] subsystem models
//! them end to end: [`domains::FailureDomainMap`] partitions the
//! deployment into nested physical domains (node → rack/PSU → UB plane),
//! [`domains::CorrelatedProfile`] samples a domain per incident and emits
//! [`faults::FaultKind::RackLoss`] events the sim expands into the full
//! member cascade (every member instance crashes within one heartbeat,
//! pool servers fail, and the rack's fabric links degrade via the
//! per-(plane, node-pair) [`netsim::DegradationMap`] — windows merge,
//! never shorten). The domain-aware recovery state machine (**incident →
//! mass recall → overlapped re-home → backfill**, policy
//! [`domains::ResiliencePolicy`]) folds the failure signals into the
//! elastic loop: offload donors spread across ≥ 2 domains, a domain-wide
//! incident triggers one mass `Recall` with a spike window scaled to the
//! lost-donor share, and crashed decode instances are backfilled by
//! borrowing prefill NPU groups instead of idling through the domain
//! replacement latency. The report accounts per-domain MTTR and blast
//! radius ([`metrics::DomainStats`]); the `correlated_rack_loss` scenario
//! preset, the `simulate` CLI (`--scenario correlated_rack_loss
//! [--no-resilience|--no-recovery]`) and `slo_explorer` run the
//! experiment; `rust/src/coordinator/README.md` documents the state
//! machine.
//!
//! ## Domain-aware placement (blast radius as an objective)
//!
//! The layout those domains describe is *chosen*, not given:
//! [`domains::PlacementPlanner`] plans the deployment under
//! [`config::PlacementObjective`] (`Packed` locality — the bit-exact
//! default — vs `SpreadRacks` rack anti-affinity vs `SpreadPlanes` UB
//! sub-plane striping), guaranteeing spread is never worse than packed on
//! blast radius while pricing the marginal cross-rack locality tax into
//! every prefill batch and decode step; the trade lands in a scored
//! [`domains::PlacementReport`]. Flows are plane-attributed (KV pushes,
//! UB pool fetches, dispatch/combine are homed on their component's UB
//! sub-plane), so [`faults::FaultKind::PlaneBrownout`] incidents degrade
//! *only* plane-homed flows via scoped [`netsim::DegradationMap`] windows
//! (single-plane fallback = the legacy whole-fabric model, bit-exact),
//! accounted per plane in [`metrics::ServingReport::plane_exposure_us`].
//! `simulate --placement spread_racks --scenario correlated_rack_loss`
//! and the `slo_explorer` packed-vs-spread legs run the experiment;
//! `integration_placement` holds the strict goodput/availability win.
//!
//! ## Sessions (prefix-cache affinity + MTP in the hot loop)
//!
//! The `session_chat` / `agentic_loop` scenario presets emit multi-turn
//! chat and agentic tool-loop sessions whose follow-up turns carry
//! *materialized* token prefixes — the full history plus a short new
//! turn. The serving loop turns the shared prefix into throughput three
//! ways: [`cache::ContextCache`] prices each arrival's longest cached
//! block-prefix as a UB pool fetch instead of re-prefill (misses and
//! [`mempool::MemPool`]-evicted blocks pay full prefill, Fig 23);
//! SGLang-style cache-affinity routing
//! ([`coordinator::router::Router::route_affinity`]) prefers the prefill
//! instance that served the session's previous turn — a local hit skips
//! even the pool fetch — yielding to the least-loaded instance when the
//! affine queue exceeds
//! [`coordinator::sim::AFFINITY_OVERLOAD_FACTOR`]; and decode runs the
//! paper's MTP speculative step (Fig 22b), emitting a second token per
//! slot-step at the configured acceptance rate, bit-exactly single-token
//! when disabled. `simulate --scenario session_chat
//! [--no-cache-affinity] [--no-mtp]` runs the ablations; the report adds
//! [`metrics::ServingReport::cache_hit_rate`] /
//! [`metrics::ServingReport::mtp_acceptance`] /
//! [`metrics::ServingReport::reprefill_frac`]; prefill/decode telemetry
//! spans carry `cache_hit`/`cache_miss`/`mtp` args; length-only presets
//! never engage any of it and stay bit-identical
//! (`tests/integration_session.rs`, `BENCH_session.json`).
//!
//! ## Fleet (multi-supernode serving)
//!
//! One CloudMatrix384 is the unit the UB fabric scales to; a production
//! region runs *many*. The [`fleet`] layer models N supernodes behind a
//! global admission router: each pod wraps the full
//! [`coordinator::sim::ServeSim`], and [`fleet::FleetRouter`] places
//! *sessions* across pods with the same queue-ratio affinity test the
//! instance router applies — a session stays on the pod holding its
//! cached prefix unless that pod's backlog exceeds the least-loaded
//! pod's by [`fleet::FLEET_OVERLOAD_FACTOR`]. When a session does
//! re-home across pods, its prefix is imported over the inter-supernode
//! RDMA plane ([`netsim::NetSim::xpod_kv_us`] — *not* the UB fabric)
//! and attribution carves the cost out as the `rdma_import` component;
//! a pod drained for maintenance ([`faults::PodDrainPlan`], the
//! supernode-granularity failure domain above
//! [`domains::FleetDomainMap`]) admits nothing and its sessions pay a
//! full cross-pod re-prefill instead. The `fleet_diurnal` scenario
//! (session chat under a diurnal wave) plus `simulate --supernodes N
//! [--no-fleet-affinity]` run the experiment; `--supernodes 1` is
//! bit-exact with the single-supernode path
//! (`tests/integration_fleet.rs`, `BENCH_fleet.json`).
//!
//! ## Observability (span traces, samplers, incident annotations)
//!
//! The [`telemetry`] subsystem keeps the *timeline* the end-of-run
//! [`metrics::ServingReport`] collapses away: per-request phase spans
//! (prefill queue → prefill → KV transfer → decode, plus the re-home /
//! re-prefill / KV-re-fetch recovery sub-spans), interval samples of
//! queue depths / live instances / pool occupancy / rolling per-tier SLO
//! attainment, and fault / resplit / offload annotations on the same
//! clock — exported as Chrome trace-event JSON (loadable in Perfetto)
//! and JSONL via `simulate --trace-out t.json --metrics-out m.jsonl`.
//! Recording is opt-in ([`coordinator::sim::SimOptions::telemetry`]) and
//! zero-cost when off: hooks are a null check, the sampler rides the
//! dispatch loop instead of the event heap, and same-seed runs are
//! bit-identical with telemetry on or off (`tests/telemetry.rs`).
//!
//! ## Attribution (turning telemetry into answers)
//!
//! The analysis layer over those streams — all export-time, so the
//! zero-cost contract is untouched. [`telemetry::attrib`] decomposes
//! every terminal request's wall time into named waterfall components
//! (admission queue, pool fetch, prefill, KV transfer, decode queue,
//! decode, and the recovery sub-phases) with a **bit-exact conservation
//! guarantee**: span boundaries are quantized to integer nanoseconds so
//! the components telescope to exactly the end-to-end latency, and any
//! gap would land in an explicit `unattributed` residual pinned to zero
//! by `tests/attrib.rs`. The same artifact reconciles the NPU-time
//! ledger (`busy + idle == assigned` per role, `prefill + decode +
//! unassigned == deployed` overall, tied to the accounting integrals).
//! [`telemetry::burn`] turns the rolling per-tier attainment windows
//! into SRE-style error-budget burn rates (fast/slow multi-window
//! alerting), exported per line in the metrics JSONL. [`telemetry::diff`]
//! compares two artifacts and names the component that moved.
//!
//! Worked example — "what did turning MTP off cost?":
//!
//! ```text
//! $ cm-infer simulate --scenario session_chat --requests 300 --attrib-out a.json
//! $ cm-infer simulate --scenario session_chat --requests 300 --no-mtp --attrib-out b.json
//! $ cm-infer attrib diff a.json b.json
//! top mover: decode (tier 0): mean 9421873.2 → 16017184.9 µs/request (+6595311.7), share 91.2% → 94.6%
//! ```
//!
//! The decode component moved; everything else is flat — the ablation's
//! cost is named, not inferred. CLI: `simulate --attrib-out PATH`,
//! `attrib diff A B`; per-leg artifacts from `slo_explorer --scenario …
//! --trace-out BASE` land at `BASE.leg<i>.attrib.json`.
//!
//! See DESIGN.md for the full system inventory and the per-experiment index
//! mapping every paper table/figure to a module and bench target.

pub mod benchlib;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod domains;
pub mod faults;
pub mod fleet;
pub mod mempool;
pub mod metrics;
pub mod netsim;
pub mod proptest;
pub mod runtime;
pub mod simnpu;
pub mod telemetry;
pub mod topology;
pub mod util;
pub mod workload;

/// Microseconds as the simulation's native time unit (paper reports µs).
pub type Micros = f64;

/// Bytes.
pub type Bytes = u64;
