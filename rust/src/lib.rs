//! # cm-infer — CloudMatrix-Infer reproduction
//!
//! A three-layer reproduction of *"Serving Large Language Models on Huawei
//! CloudMatrix384"* (Zuo et al., 2025):
//!
//! * **Layer 3 (this crate)** — the paper's serving system: a peer-to-peer
//!   prefill–decode–caching (PDC) disaggregated coordinator, large-scale
//!   expert parallelism (LEP), microbatch pipelines, MTP speculative
//!   decoding, a UB-driven disaggregated memory pool with context/model
//!   caching, and a calibrated discrete simulation of the CloudMatrix384
//!   supernode substrate (topology, network planes, Ascend 910C dies).
//! * **Layer 2/1 (python/, build-time only)** — a JAX MoE transformer with
//!   MLA attention and Pallas kernels, AOT-lowered to HLO text artifacts
//!   that [`runtime`] loads and executes through PJRT. Python never runs on
//!   the request path.
//!
//! See DESIGN.md for the full system inventory and the per-experiment index
//! mapping every paper table/figure to a module and bench target.

pub mod benchlib;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod mempool;
pub mod metrics;
pub mod netsim;
pub mod proptest;
pub mod runtime;
pub mod simnpu;
pub mod topology;
pub mod util;
pub mod workload;

/// Microseconds as the simulation's native time unit (paper reports µs).
pub type Micros = f64;

/// Bytes.
pub type Bytes = u64;
