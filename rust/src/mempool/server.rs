//! MP Server (paper §4.4.1): per-node memory management — huge-page arena
//! accounting, multi-granularity allocation, DRAM→SSD (EVS) tiering with
//! LRU eviction, persistence and crash recovery.
//!
//! Data is tracked by (namespace, key) → block descriptor; payloads are
//! simulated by size. Allocation models the paper's huge-page + variable-
//! length partition scheme by accounting fragmentation at huge-page
//! granularity for large blocks and slab granularity for small ones.

use std::collections::BTreeMap;

use super::controller::NamespaceId;
use super::Key;

/// Residency tier of a block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    Dram,
    Ssd,
}

/// Huge-page size used for large-block accounting (2 MiB).
pub const HUGE_PAGE: u64 = 2 << 20;
/// Slab granularity for small blocks (4 KiB).
pub const SLAB: u64 = 4 << 10;

/// Rounded allocation footprint of a block (multi-granularity alloc).
pub fn alloc_footprint(bytes: u64) -> u64 {
    if bytes >= HUGE_PAGE {
        bytes.div_ceil(HUGE_PAGE) * HUGE_PAGE
    } else {
        bytes.div_ceil(SLAB) * SLAB
    }
}

#[derive(Debug, Clone)]
struct Block {
    bytes: u64,
    tier: Tier,
    /// Persisted to EVS (write-through, §4.4.1 "persistence is enforced by
    /// writing all data to EVS").
    persisted: bool,
    /// LRU stamp: monotonic access counter (O(log n) LRU via `lru_index`).
    last_used: u64,
}

/// Result of a Get against one server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GetResult {
    Dram(u64),
    Ssd(u64),
    Miss,
}

/// Result of a Put.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PutOutcome {
    Stored,
    /// Needed LRU eviction(s) to make room.
    EvictedThenStored,
    /// Identical key already present (content-addressed dedup).
    AlreadyPresent,
    /// Larger than total capacity.
    Rejected,
}

/// Aggregatable server statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerStats {
    pub dram_used: u64,
    pub ssd_used: u64,
    pub blocks_dram: usize,
    pub blocks_ssd: usize,
    pub evictions_to_ssd: u64,
    pub evictions_dropped: u64,
    pub dedup_hits: u64,
}

/// One DRAM-contributing node of the pool.
///
/// LRU is index-based (Perf pass, EXPERIMENTS.md §Perf): a monotonic access
/// counter stamps each DRAM block; `lru_index` maps stamp → block id, so
/// touch and evict are O(log n) instead of the original O(n) VecDeque scan
/// that dominated the pool hot path.
#[derive(Debug)]
pub struct Server {
    pub id: usize,
    dram_capacity: u64,
    ssd_capacity: u64,
    dram_used: u64,
    ssd_used: u64,
    blocks: BTreeMap<(NamespaceId, Key), Block>,
    /// stamp → DRAM-resident block id (coldest = smallest stamp).
    lru_index: BTreeMap<u64, (NamespaceId, Key)>,
    clock: u64,
    stats: ServerStats,
}

impl Server {
    pub fn new(id: usize, dram_capacity: u64, ssd_capacity: u64) -> Server {
        Server {
            id,
            dram_capacity,
            ssd_capacity,
            dram_used: 0,
            ssd_used: 0,
            blocks: BTreeMap::new(),
            lru_index: BTreeMap::new(),
            clock: 0,
            stats: ServerStats::default(),
        }
    }

    fn touch(&mut self, id: (NamespaceId, Key)) {
        self.clock += 1;
        let stamp = self.clock;
        if let Some(block) = self.blocks.get_mut(&id) {
            self.lru_index.remove(&block.last_used);
            block.last_used = stamp;
        }
        self.lru_index.insert(stamp, id);
    }

    /// Evict coldest DRAM blocks until `needed` bytes fit; demote to SSD if
    /// space allows, else drop entirely (LRU policy, §4.4.1).
    fn make_room(&mut self, needed: u64) -> bool {
        if needed > self.dram_capacity {
            return false;
        }
        while self.dram_used + needed > self.dram_capacity {
            let Some((&stamp, &victim)) = self.lru_index.iter().next() else {
                return false;
            };
            self.lru_index.remove(&stamp);
            let Some(block) = self.blocks.get_mut(&victim) else {
                continue;
            };
            let fp = alloc_footprint(block.bytes);
            self.dram_used -= fp;
            if block.persisted && self.ssd_used + fp <= self.ssd_capacity {
                block.tier = Tier::Ssd;
                // EVS copy already exists (write-through) — no extra bytes
                self.stats.evictions_to_ssd += 1;
            } else if self.ssd_used + fp <= self.ssd_capacity {
                block.tier = Tier::Ssd;
                self.ssd_used += fp;
                self.stats.evictions_to_ssd += 1;
            } else {
                self.blocks.remove(&victim);
                self.stats.evictions_dropped += 1;
            }
        }
        true
    }

    pub fn put(&mut self, ns: NamespaceId, key: Key, bytes: u64) -> PutOutcome {
        let id = (ns, key);
        if self.blocks.contains_key(&id) {
            self.stats.dedup_hits += 1;
            self.touch(id);
            return PutOutcome::AlreadyPresent;
        }
        let fp = alloc_footprint(bytes);
        let evicted = self.dram_used + fp > self.dram_capacity;
        if !self.make_room(fp) {
            return PutOutcome::Rejected;
        }
        self.dram_used += fp;
        // write-through persistence to EVS when it has room
        let persisted = self.ssd_used + fp <= self.ssd_capacity;
        if persisted {
            self.ssd_used += fp;
        }
        self.clock += 1;
        let stamp = self.clock;
        self.blocks.insert(id, Block { bytes, tier: Tier::Dram, persisted, last_used: stamp });
        self.lru_index.insert(stamp, id);
        if evicted {
            PutOutcome::EvictedThenStored
        } else {
            PutOutcome::Stored
        }
    }

    pub fn get(&mut self, ns: NamespaceId, key: Key) -> GetResult {
        let id = (ns, key);
        let Some(block) = self.blocks.get(&id) else {
            return GetResult::Miss;
        };
        let bytes = block.bytes;
        match block.tier {
            Tier::Dram => {
                self.touch(id);
                GetResult::Dram(bytes)
            }
            Tier::Ssd => {
                // promote back to DRAM if possible (re-warm)
                let fp = alloc_footprint(bytes);
                if self.make_room(fp) {
                    self.dram_used += fp;
                    self.clock += 1;
                    let stamp = self.clock;
                    let b = self.blocks.get_mut(&id).unwrap();
                    b.tier = Tier::Dram;
                    b.last_used = stamp;
                    self.lru_index.insert(stamp, id);
                }
                GetResult::Ssd(bytes)
            }
        }
    }

    pub fn delete(&mut self, ns: NamespaceId, key: Key) -> bool {
        let id = (ns, key);
        if let Some(block) = self.blocks.remove(&id) {
            let fp = alloc_footprint(block.bytes);
            if block.tier == Tier::Dram {
                self.dram_used -= fp;
                self.lru_index.remove(&block.last_used);
            }
            if block.persisted || block.tier == Tier::Ssd {
                self.ssd_used = self.ssd_used.saturating_sub(fp);
            }
            true
        } else {
            false
        }
    }

    /// Crash: volatile DRAM lost; persisted blocks survive on EVS and are
    /// served from the SSD tier. Returns (lost, recoverable).
    pub fn crash(&mut self) -> (usize, usize) {
        let mut lost = 0;
        let mut recoverable = 0;
        self.lru_index.clear();
        self.dram_used = 0;
        self.blocks.retain(|_, b| {
            if b.persisted {
                b.tier = Tier::Ssd;
                recoverable += 1;
                true
            } else {
                lost += 1;
                false
            }
        });
        (lost, recoverable)
    }

    pub fn stats(&self) -> ServerStats {
        let mut s = self.stats;
        s.dram_used = self.dram_used;
        s.ssd_used = self.ssd_used;
        s.blocks_dram = self.blocks.values().filter(|b| b.tier == Tier::Dram).count();
        s.blocks_ssd = self.blocks.values().filter(|b| b.tier == Tier::Ssd).count();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ns() -> NamespaceId {
        NamespaceId(1)
    }

    fn key(i: u32) -> Key {
        Key::of_bytes(&i.to_le_bytes())
    }

    #[test]
    fn footprint_granularity() {
        assert_eq!(alloc_footprint(1), SLAB);
        assert_eq!(alloc_footprint(SLAB), SLAB);
        assert_eq!(alloc_footprint(SLAB + 1), 2 * SLAB);
        assert_eq!(alloc_footprint(HUGE_PAGE), HUGE_PAGE);
        assert_eq!(alloc_footprint(HUGE_PAGE + 1), 2 * HUGE_PAGE);
    }

    #[test]
    fn lru_evicts_coldest_first() {
        let mut s = Server::new(0, 3 * SLAB, 100 * SLAB);
        s.put(ns(), key(1), SLAB);
        s.put(ns(), key(2), SLAB);
        s.put(ns(), key(3), SLAB);
        // touch key 1 so key 2 becomes coldest
        assert!(matches!(s.get(ns(), key(1)), GetResult::Dram(_)));
        let out = s.put(ns(), key(4), SLAB);
        assert_eq!(out, PutOutcome::EvictedThenStored);
        // key 2 went to SSD; key 1 still in DRAM
        assert!(matches!(s.get(ns(), key(2)), GetResult::Ssd(_)));
        assert!(matches!(s.get(ns(), key(1)), GetResult::Dram(_) | GetResult::Ssd(_)));
    }

    #[test]
    fn rejects_oversized() {
        let mut s = Server::new(0, 2 * SLAB, 0);
        assert_eq!(s.put(ns(), key(1), 10 * SLAB), PutOutcome::Rejected);
    }

    #[test]
    fn ssd_promotion_on_access() {
        let mut s = Server::new(0, 2 * SLAB, 100 * SLAB);
        s.put(ns(), key(1), SLAB);
        s.put(ns(), key(2), SLAB);
        s.put(ns(), key(3), SLAB); // evicts key 1 to SSD
        assert!(matches!(s.get(ns(), key(1)), GetResult::Ssd(_)));
        // second access should find it re-warmed in DRAM
        assert!(matches!(s.get(ns(), key(1)), GetResult::Dram(_)));
    }

    #[test]
    fn delete_frees_space() {
        let mut s = Server::new(0, 2 * SLAB, 100 * SLAB);
        s.put(ns(), key(1), SLAB);
        s.put(ns(), key(2), SLAB);
        assert!(s.delete(ns(), key(1)));
        assert!(!s.delete(ns(), key(1)));
        // room for a new block without eviction
        assert_eq!(s.put(ns(), key(3), SLAB), PutOutcome::Stored);
    }

    #[test]
    fn crash_preserves_persisted_only() {
        let mut s = Server::new(0, 10 * SLAB, 2 * SLAB); // small SSD
        s.put(ns(), key(1), SLAB); // persisted (SSD has room)
        s.put(ns(), key(2), SLAB); // persisted
        s.put(ns(), key(3), SLAB); // NOT persisted (SSD full)
        let (lost, recoverable) = s.crash();
        assert_eq!(lost, 1);
        assert_eq!(recoverable, 2);
        assert!(matches!(s.get(ns(), key(1)), GetResult::Ssd(_) | GetResult::Dram(_)));
        assert_eq!(s.get(ns(), key(3)), GetResult::Miss);
    }

    #[test]
    fn accounting_never_goes_negative() {
        let mut s = Server::new(0, 4 * SLAB, 8 * SLAB);
        for i in 0..50 {
            s.put(ns(), key(i), SLAB);
            if i % 3 == 0 {
                s.delete(ns(), key(i / 2));
            }
        }
        let st = s.stats();
        assert!(st.dram_used <= 4 * SLAB);
        assert!(st.ssd_used <= 8 * SLAB);
    }
}
