//! MP Controller (paper §4.4.1): the centralized control plane holding the
//! DHT view (consistent hashing with virtual nodes), namespace metadata and
//! membership. Placement is *computed* by SDK clients from the view — the
//! controller is not on the data path, matching the paper's design.

use std::collections::BTreeMap;

use super::Key;

/// Namespace identity (Context Caching vs Model Caching instances, tenants).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NamespaceId(pub u32);

/// Namespace metadata.
#[derive(Debug, Clone)]
pub struct Namespace {
    pub id: NamespaceId,
    pub name: String,
    /// Optional byte quota ("capacity usage limitation", §4.4.1).
    pub quota_bytes: Option<u64>,
}

/// Consistent-hash ring view distributed to SDK clients.
#[derive(Debug, Clone)]
pub struct DhtView {
    /// (ring position, server id), sorted by position.
    ring: Vec<(u64, usize)>,
    pub epoch: u64,
}

const VNODES_PER_SERVER: usize = 64;

fn vnode_pos(server: usize, replica: usize) -> u64 {
    // splitmix-style mix of (server, replica)
    let mut x = (server as u64) << 32 | replica as u64;
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

impl DhtView {
    pub fn new(servers: &[usize]) -> DhtView {
        let mut ring = Vec::with_capacity(servers.len() * VNODES_PER_SERVER);
        for &s in servers {
            for r in 0..VNODES_PER_SERVER {
                ring.push((vnode_pos(s, r), s));
            }
        }
        ring.sort_unstable();
        DhtView { ring, epoch: 0 }
    }

    /// Owning server for a key: first vnode clockwise from the key's hash.
    pub fn place(&self, key: Key) -> usize {
        assert!(!self.ring.is_empty(), "empty DHT ring");
        let h = (key.0 >> 64) as u64 ^ key.0 as u64;
        match self.ring.binary_search_by(|&(pos, _)| pos.cmp(&h)) {
            Ok(i) => self.ring[i].1,
            Err(i) => self.ring[i % self.ring.len()].1,
        }
    }

    /// Remove a failed server from the ring (its keys re-home clockwise).
    pub fn remove_server(&mut self, server: usize) {
        self.ring.retain(|&(_, s)| s != server);
        self.epoch += 1;
    }

    pub fn server_count(&self) -> usize {
        let mut ids: Vec<usize> = self.ring.iter().map(|&(_, s)| s).collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    }
}

/// The control plane.
#[derive(Debug)]
pub struct Controller {
    pub view: DhtView,
    namespaces: BTreeMap<NamespaceId, Namespace>,
    next_ns: u32,
}

impl Controller {
    pub fn new(n_servers: usize) -> Controller {
        let servers: Vec<usize> = (0..n_servers).collect();
        Controller { view: DhtView::new(&servers), namespaces: BTreeMap::new(), next_ns: 1 }
    }

    pub fn create_namespace(&mut self, name: &str) -> NamespaceId {
        self.create_namespace_with_quota(name, None)
    }

    pub fn create_namespace_with_quota(
        &mut self,
        name: &str,
        quota_bytes: Option<u64>,
    ) -> NamespaceId {
        let id = NamespaceId(self.next_ns);
        self.next_ns += 1;
        self.namespaces.insert(id, Namespace { id, name: name.to_string(), quota_bytes });
        id
    }

    pub fn namespace(&self, id: NamespaceId) -> Option<&Namespace> {
        self.namespaces.get(&id)
    }

    pub fn delete_namespace(&mut self, id: NamespaceId) -> bool {
        self.namespaces.remove(&id).is_some()
    }

    /// SDK-side placement through the current view.
    pub fn place(&self, key: Key) -> usize {
        self.view.place(key)
    }

    /// Membership change on failure.
    pub fn mark_failed(&mut self, server: usize) {
        self.view.remove_server(server);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_is_deterministic_and_covers_servers() {
        let c = Controller::new(8);
        let mut seen = vec![false; 8];
        for i in 0..2000u32 {
            let k = Key::of_bytes(&i.to_le_bytes());
            let s = c.place(k);
            assert_eq!(s, c.place(k));
            seen[s] = true;
        }
        assert!(seen.iter().all(|&s| s), "all servers should own some keys");
    }

    #[test]
    fn placement_is_balanced() {
        let c = Controller::new(8);
        let mut counts = vec![0usize; 8];
        for i in 0..8000u32 {
            counts[c.place(Key::of_bytes(&i.to_le_bytes()))] += 1;
        }
        let min = *counts.iter().min().unwrap() as f64;
        let max = *counts.iter().max().unwrap() as f64;
        assert!(max / min < 2.0, "imbalanced ring: {counts:?}");
    }

    #[test]
    fn removal_only_rehomes_victims_keys() {
        let mut c = Controller::new(8);
        let keys: Vec<Key> = (0..4000u32).map(|i| Key::of_bytes(&i.to_le_bytes())).collect();
        let before: Vec<usize> = keys.iter().map(|&k| c.place(k)).collect();
        c.mark_failed(3);
        let mut moved_not_from_victim = 0;
        for (k, &b) in keys.iter().zip(&before) {
            let a = c.place(*k);
            assert_ne!(a, 3, "failed server still owns keys");
            if b != 3 && a != b {
                moved_not_from_victim += 1;
            }
        }
        // consistent hashing: only the victim's keys move
        assert_eq!(moved_not_from_victim, 0);
        assert_eq!(c.view.server_count(), 7);
    }

    #[test]
    fn namespace_lifecycle() {
        let mut c = Controller::new(2);
        let ns = c.create_namespace_with_quota("kv", Some(1 << 30));
        assert_eq!(c.namespace(ns).unwrap().name, "kv");
        assert_eq!(c.namespace(ns).unwrap().quota_bytes, Some(1 << 30));
        assert!(c.delete_namespace(ns));
        assert!(c.namespace(ns).is_none());
        assert!(!c.delete_namespace(ns));
    }
}
