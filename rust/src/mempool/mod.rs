//! UB-driven disaggregated memory pool (paper §4.4.1) — the substrate under
//! EMS context/model caching.
//!
//! Three components, mirroring the paper's architecture:
//!
//! * [`Controller`] — control plane: DHT view (consistent hashing),
//!   namespaces, membership, recovery orchestration.
//! * [`Server`] — one per DRAM-contributing CPU node: local allocation
//!   (huge-page arenas, multi-granularity), DRAM↔SSD (EVS) tiering with
//!   LRU eviction, persistence.
//! * [`Sdk`] — the Put/Get key-value API embedded in engines; computes
//!   placement via the DHT and charges transfer costs to the [`NetSim`]
//!   planes (UB by default, VPC fallback for the Fig. 23 ablation).
//!
//! All data is *simulated by size* (we track bytes and block identity, not
//! payloads) but the structure — hashing, placement, eviction, tier
//! residency, recovery — is fully executable and property-tested.

mod controller;
mod server;

pub use controller::{Controller, DhtView, Namespace, NamespaceId};
pub use server::{GetResult, PutOutcome, Server, ServerStats, Tier};

use crate::netsim::{Locality, NetSim, OpKind, PathKind, Plane};
use crate::Micros;

/// A key in the pool: 128-bit content/identity hash.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Key(pub u128);

impl Key {
    /// FNV-1a over arbitrary bytes, widened to 128 bits by double hashing.
    pub fn of_bytes(bytes: &[u8]) -> Key {
        let mut h1: u64 = 0xcbf29ce484222325;
        for &b in bytes {
            h1 ^= b as u64;
            h1 = h1.wrapping_mul(0x100000001b3);
        }
        let mut h2: u64 = 0x9e3779b97f4a7c15;
        for &b in bytes {
            h2 = (h2 ^ b as u64).wrapping_mul(0xff51afd7ed558ccd);
            h2 ^= h2 >> 33;
        }
        Key(((h1 as u128) << 64) | h2 as u128)
    }

    /// Content hash of a token chunk — equivalent strength to `of_bytes`
    /// over the little-endian encoding, but word-at-a-time and
    /// allocation-free (Perf pass: the context-cache keying hot path).
    pub fn of_tokens(tokens: &[i32]) -> Key {
        let mut h1: u64 = 0xcbf29ce484222325;
        let mut h2: u64 = 0x9e3779b97f4a7c15;
        for &t in tokens {
            let w = t as u32 as u64;
            h1 = (h1 ^ w).wrapping_mul(0x100000001b3);
            h1 ^= h1 >> 29;
            h2 = (h2 ^ w.rotate_left(17)).wrapping_mul(0xff51afd7ed558ccd);
            h2 ^= h2 >> 33;
        }
        Key(((h1 as u128) << 64) | h2 as u128)
    }

    /// Chain hash: parent prefix hash + this block's content hash
    /// (content-addressable prefix indexing, §4.4.2).
    pub fn chain(parent: Key, child: Key) -> Key {
        let mixed = parent.0.wrapping_mul(0x2d358dccaa6c78a5_5851f42d4c957f2d)
            ^ child.0.rotate_left(64);
        Key(mixed)
    }
}

/// The assembled pool: controller + servers + SDK entry points.
pub struct MemPool {
    pub controller: Controller,
    pub servers: Vec<Server>,
    pub net: NetSim,
}

/// Outcome of an SDK Get: where the data was found and the modeled cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AccessOutcome {
    pub hit: bool,
    pub tier: Option<Tier>,
    pub server: Option<usize>,
    pub latency_us: Micros,
    pub bytes: u64,
}

impl MemPool {
    /// Build a pool over `n_servers` DRAM-contributing nodes.
    pub fn new(n_servers: usize, dram_capacity_bytes: u64, ssd_capacity_bytes: u64) -> MemPool {
        let controller = Controller::new(n_servers);
        let servers = (0..n_servers)
            .map(|i| Server::new(i, dram_capacity_bytes, ssd_capacity_bytes))
            .collect();
        MemPool { controller, servers, net: NetSim::default() }
    }

    /// SDK Put: place `bytes` under `key` in `ns`, charging a UB write.
    pub fn put(&mut self, ns: NamespaceId, key: Key, bytes: u64) -> AccessOutcome {
        let sid = self.controller.place(key);
        let outcome = self.servers[sid].put(ns, key, bytes);
        let latency = match outcome {
            PutOutcome::Stored | PutOutcome::EvictedThenStored => self.net.transfer_us(
                Plane::Ub,
                PathKind::NpuToCpu,
                OpKind::Write,
                Locality::InterNode,
                bytes,
            ),
            // dedup hit: only metadata travels
            PutOutcome::AlreadyPresent => self.net.transfer_us(
                Plane::Ub,
                PathKind::NpuToCpu,
                OpKind::Write,
                Locality::InterNode,
                64,
            ),
            PutOutcome::Rejected => 0.0,
        };
        AccessOutcome {
            hit: outcome != PutOutcome::Rejected,
            tier: Some(Tier::Dram),
            server: Some(sid),
            latency_us: latency,
            bytes,
        }
    }

    /// SDK Get: fetch `key`, charging the fabric (`over_ub` selects the
    /// Fig. 23 network configuration) plus the SSD tier penalty on a DRAM
    /// miss that hits EVS.
    pub fn get(&mut self, ns: NamespaceId, key: Key, over_ub: bool) -> AccessOutcome {
        let sid = self.controller.place(key);
        match self.servers[sid].get(ns, key) {
            GetResult::Dram(bytes) => {
                let plane = if over_ub { Plane::Ub } else { Plane::Vpc };
                let latency = self.net.transfer_us(
                    plane,
                    PathKind::NpuToCpu,
                    OpKind::Read,
                    Locality::InterNode,
                    bytes,
                );
                AccessOutcome {
                    hit: true,
                    tier: Some(Tier::Dram),
                    server: Some(sid),
                    latency_us: latency,
                    bytes,
                }
            }
            GetResult::Ssd(bytes) => {
                // EVS read into DRAM, then fabric to the NPU
                let ssd = self.net.evs_node.transfer_us(bytes);
                let plane = if over_ub { Plane::Ub } else { Plane::Vpc };
                let fabric = self.net.transfer_us(
                    plane,
                    PathKind::NpuToCpu,
                    OpKind::Read,
                    Locality::InterNode,
                    bytes,
                );
                AccessOutcome {
                    hit: true,
                    tier: Some(Tier::Ssd),
                    server: Some(sid),
                    latency_us: ssd + fabric,
                    bytes,
                }
            }
            GetResult::Miss => AccessOutcome {
                hit: false,
                tier: None,
                server: Some(sid),
                latency_us: 2.0, // DHT lookup round-trip
                bytes: 0,
            },
        }
    }

    /// SDK Delete: drop `key` from its placed server (all tiers); returns
    /// whether a block was actually removed. Deletion is metadata-only in
    /// the real system, so no transfer cost is charged.
    pub fn delete(&mut self, ns: NamespaceId, key: Key) -> bool {
        let sid = self.controller.place(key);
        self.servers[sid].delete(ns, key)
    }

    /// Fail a server: DRAM contents lost; EVS-persisted blocks recoverable.
    /// Returns (blocks_lost, blocks_recoverable) — §4.4.1 fault resilience.
    pub fn fail_server(&mut self, sid: usize) -> (usize, usize) {
        self.servers[sid].crash()
    }

    /// Aggregate stats across servers.
    pub fn stats(&self) -> ServerStats {
        let mut agg = ServerStats::default();
        for s in &self.servers {
            let st = s.stats();
            agg.dram_used += st.dram_used;
            agg.ssd_used += st.ssd_used;
            agg.blocks_dram += st.blocks_dram;
            agg.blocks_ssd += st.blocks_ssd;
            agg.evictions_to_ssd += st.evictions_to_ssd;
            agg.evictions_dropped += st.evictions_dropped;
            agg.dedup_hits += st.dedup_hits;
        }
        agg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> MemPool {
        MemPool::new(4, 1 << 20, 4 << 20) // 1 MiB DRAM, 4 MiB SSD per server
    }

    #[test]
    fn put_get_roundtrip() {
        let mut p = pool();
        let ns = p.controller.create_namespace("ctx");
        let k = Key::of_bytes(b"block-1");
        let put = p.put(ns, k, 4096);
        assert!(put.hit);
        let got = p.get(ns, k, true);
        assert!(got.hit);
        assert_eq!(got.tier, Some(Tier::Dram));
        assert_eq!(got.bytes, 4096);
        assert_eq!(got.server, put.server);
    }

    #[test]
    fn miss_reports_cleanly() {
        let mut p = pool();
        let ns = p.controller.create_namespace("ctx");
        let got = p.get(ns, Key::of_bytes(b"nope"), true);
        assert!(!got.hit);
        assert_eq!(got.bytes, 0);
    }

    #[test]
    fn namespaces_isolate() {
        let mut p = pool();
        let a = p.controller.create_namespace("a");
        let b = p.controller.create_namespace("b");
        let k = Key::of_bytes(b"shared-key");
        p.put(a, k, 1024);
        assert!(p.get(a, k, true).hit);
        assert!(!p.get(b, k, true).hit, "namespace b must not see a's data");
    }

    #[test]
    fn ub_get_faster_than_vpc_get() {
        let mut p = pool();
        let ns = p.controller.create_namespace("ctx");
        let k = Key::of_bytes(b"kv");
        p.put(ns, k, 512 * 1024);
        let ub = p.get(ns, k, true);
        let vpc = p.get(ns, k, false);
        assert!(vpc.latency_us / ub.latency_us > 3.0, "ub {} vpc {}", ub.latency_us, vpc.latency_us);
    }

    #[test]
    fn dram_pressure_tiers_to_ssd() {
        let mut p = pool();
        let ns = p.controller.create_namespace("ctx");
        // overflow DRAM on whichever server receives most keys
        for i in 0..64u32 {
            let k = Key::of_bytes(&i.to_le_bytes());
            p.put(ns, k, 256 * 1024);
        }
        let st = p.stats();
        assert!(st.evictions_to_ssd > 0, "expected tiering under pressure: {st:?}");
        // a cold key should still be readable (from SSD), slower
        let cold = Key::of_bytes(&0u32.to_le_bytes());
        let got = p.get(ns, cold, true);
        if got.hit && got.tier == Some(Tier::Ssd) {
            let hot = Key::of_bytes(&63u32.to_le_bytes());
            let hot_got = p.get(ns, hot, true);
            if hot_got.tier == Some(Tier::Dram) {
                assert!(got.latency_us > hot_got.latency_us);
            }
        }
    }

    #[test]
    fn failure_recovers_persisted_blocks() {
        let mut p = pool();
        let ns = p.controller.create_namespace("ctx");
        let keys: Vec<Key> = (0..16u32).map(|i| Key::of_bytes(&i.to_le_bytes())).collect();
        for &k in &keys {
            p.put(ns, k, 128 * 1024);
        }
        let victim = p.controller.place(keys[0]);
        let (lost, recoverable) = p.fail_server(victim);
        // everything written to EVS is recoverable; nothing silently vanishes
        assert_eq!(lost, 0, "persisted blocks must not be lost");
        assert!(recoverable > 0);
        // data still accessible (served from the SSD tier post-recovery)
        let got = p.get(ns, keys[0], true);
        assert!(got.hit);
    }

    #[test]
    fn sdk_delete_frees_the_placed_copy() {
        let mut p = pool();
        let ns = p.controller.create_namespace("ctx");
        let k = Key::of_bytes(b"ephemeral");
        p.put(ns, k, 8192);
        assert!(p.get(ns, k, true).hit);
        assert!(p.delete(ns, k));
        assert!(!p.get(ns, k, true).hit, "deleted key must miss");
        assert!(!p.delete(ns, k), "double delete is a no-op");
    }

    #[test]
    fn dedup_detects_repeat_put() {
        let mut p = pool();
        let ns = p.controller.create_namespace("ctx");
        let k = Key::of_bytes(b"same");
        p.put(ns, k, 4096);
        let second = p.put(ns, k, 4096);
        assert!(second.hit);
        assert_eq!(p.stats().dedup_hits, 1);
    }

    #[test]
    fn key_chain_is_order_sensitive() {
        let a = Key::of_bytes(b"a");
        let b = Key::of_bytes(b"b");
        assert_ne!(Key::chain(a, b), Key::chain(b, a));
        assert_ne!(Key::chain(a, b), a);
    }
}
