//! Table 2: model loading/switching — no-cache vs local DRAM vs EMS
//! (671 GB INT8 model, 8 instances, 2.5 GB/s OBS bucket).

use cm_infer::benchlib::{bench, finding, iters, Table};
use cm_infer::cache::model::{table2_row, Table2Params};
use cm_infer::cache::{LoadStrategy, ModelCache};
use cm_infer::mempool::MemPool;
use cm_infer::netsim::NetSim;

fn main() {
    let net = NetSim::default();
    let p = Table2Params::default();
    let rows = [
        ("No Cache (OBS Load)", LoadStrategy::NoCache),
        ("Local DRAM Cache", LoadStrategy::LocalDram),
        ("EMS", LoadStrategy::Ems),
    ];

    let mut t = Table::new(
        "Table 2 — model load/switch strategies (671 GB INT8, 8 instances)",
        &["Strategy", "Cold start (s)", "Warm start (s)", "DRAM overhead (x)",
          "Switch hit rate", "Switch latency (s)"],
    );
    for (name, strategy) in rows {
        let r = table2_row(&net, &p, strategy);
        t.row(&[
            name.into(),
            format!("~{:.0}", r.cold_start_s),
            if r.warm_start_s.is_nan() { "N/A".into() } else { format!("~{:.0}", r.warm_start_s) },
            format!("{:.0}", r.dram_overhead_x),
            format!("{:.1}%", r.switch_hit_rate * 100.0),
            format!("~{:.0}", r.switch_latency_s),
        ]);
    }
    t.print();
    finding("paper shape: EMS cuts cold start ~8x (2,560→320 s), 1x DRAM vs 8x, 100% switch hits at ~5 s (§4.4.3)");

    // executable-path benchmark: block-sharded load through the real pool
    let mut pool = MemPool::new(16, 8 << 30, 32 << 30);
    let mut mc = ModelCache::new(&mut pool);
    mc.admit(&mut pool, "bench-model", 1, 2 << 30, 32 << 20);
    let st = bench(2, iters(200), || {
        let t = mc.load_to_npu(&mut pool, "bench-model", 1).unwrap();
        cm_infer::benchlib::black_box(t);
    });
    println!("\npool block-load path (2 GiB over 16 servers): mean {:.1} µs/op", st.mean_us);
}
