//! Fig. 20: decode throughput and per-layer latency breakdown with and
//! without the two-stream microbatch pipeline (§4.2.3).

use cm_infer::benchlib::{finding, Table};
use cm_infer::config::{Ascend910cDie, DeepSeekDims};
use cm_infer::simnpu::pipeline::{decode_layer, decode_step, DecodePoint};

fn main() {
    let die = Ascend910cDie::default();
    let m = DeepSeekDims::deepseek_r1();

    // (a) throughput vs batch
    let mut t = Table::new(
        "Fig 20a — decode throughput w/ and w/o microbatch pipeline (4K KV, no MTP)",
        &["Batch/NPU", "tok/s/NPU (off)", "tok/s/NPU (on)", "gain", "paper gain"],
    );
    let paper_gain = [(64usize, 5.8), (96, 9.4), (128, 6.9)];
    for (batch, p_gain) in paper_gain {
        let on = decode_step(&die, &m, &DecodePoint {
            batch_per_npu: batch, mtp: false, ..DecodePoint::paper_reference()
        });
        let off = decode_step(&die, &m, &DecodePoint {
            batch_per_npu: batch, mtp: false, microbatch: false, ..DecodePoint::paper_reference()
        });
        let gain = (on.tokens_per_s_per_npu / off.tokens_per_s_per_npu - 1.0) * 100.0;
        t.row(&[
            format!("{batch}"),
            format!("{:.0}", off.tokens_per_s_per_npu),
            format!("{:.0}", on.tokens_per_s_per_npu),
            format!("+{gain:.1}%"),
            format!("+{p_gain:.1}%"),
        ]);
    }
    t.print();

    // (b) per-layer latency breakdown at batch 96
    let on = decode_layer(&die, &m, &DecodePoint {
        batch_per_npu: 96, mtp: false, ..DecodePoint::paper_reference()
    });
    let off = decode_layer(&die, &m, &DecodePoint {
        batch_per_npu: 96, mtp: false, microbatch: false, ..DecodePoint::paper_reference()
    });
    let mut t = Table::new(
        "Fig 20b — per-layer latency breakdown, batch 96 (µs)",
        &["Operator", "w/o microbatch", "with microbatch"],
    );
    for (name, a, b) in [
        ("MLAProlog", off.mla_prolog, on.mla_prolog),
        ("AttentionCore", off.attn_core, on.attn_core),
        ("O_PROJ", off.o_proj, on.o_proj),
        ("Gate", off.gate, on.gate),
        ("Dispatch", off.dispatch, on.dispatch),
        ("MoE MLP", off.moe_mlp, on.moe_mlp),
        ("Combine", off.combine, on.combine),
        ("Stream 0 total", off.stream0, on.stream0),
        ("Stream 1 total", off.stream1, on.stream1),
        ("Overall / layer", off.layer, on.layer),
    ] {
        t.row(&[name.into(), format!("{a:.0}"), format!("{b:.0}")]);
    }
    t.print();
    finding(&format!(
        "paper shape: individual ops slightly slower under partitioned resources, but overlapping the two streams cuts overall per-layer latency ~10% (model: {:.1}%)",
        (1.0 - on.layer / off.layer) * 100.0
    ));
    finding("paper notes the gain is modest vs NVIDIA clusters (SGLang +35%) because UB keeps MoE comm small to begin with (§5.4.1)");
}
