//! Ablation (§4.3.1): staged hybrid parallelism (SP→TP→SP) vs pure DP for
//! prefill MLA under sequence-length skew.

use cm_infer::benchlib::{finding, Table};
use cm_infer::config::{Ascend910cDie, DeepSeekDims};
use cm_infer::simnpu::pipeline::{prefill_model, PrefillPoint};
use cm_infer::util::Rng;

fn main() {
    let die = Ascend910cDie::default();
    let m = DeepSeekDims::deepseek_r1();

    // measure realistic length skew from the workload generator
    let mut rng = Rng::new(1);
    let mut skews = Vec::new();
    for _ in 0..200 {
        let lens: Vec<f64> = (0..32).map(|_| rng.lognormal(8.1, 0.6).clamp(64.0, 16384.0)).collect();
        let mean = lens.iter().sum::<f64>() / lens.len() as f64;
        let max = lens.iter().cloned().fold(0.0f64, f64::max);
        skews.push(max / mean);
    }
    let mean_skew = skews.iter().sum::<f64>() / skews.len() as f64;
    println!("measured DP32 straggler skew on log-normal prompts: {mean_skew:.2}x\n");

    let mut t = Table::new(
        "Ablation — hybrid parallelism vs pure DP for prefill MLA",
        &["Length skew", "pure DP tok/s/NPU", "hybrid tok/s/NPU", "hybrid gain"],
    );
    for skew in [1.0, 1.2, mean_skew, 2.0, 3.0] {
        let base = PrefillPoint { length_skew: skew, ..PrefillPoint::paper_reference(false) };
        let hybrid = prefill_model(&die, &m, &base);
        let dp = prefill_model(&die, &m, &PrefillPoint { hybrid_parallelism: false, ..base });
        t.row(&[
            format!("{skew:.2}x"),
            format!("{:.0}", dp.tokens_per_s_per_npu),
            format!("{:.0}", hybrid.tokens_per_s_per_npu),
            format!("+{:.0}%", (hybrid.tokens_per_s_per_npu / dp.tokens_per_s_per_npu - 1.0) * 100.0),
        ]);
    }
    t.print();
    finding("SP packing spreads tokens uniformly regardless of request lengths, so the hybrid scheme's advantage grows with skew — the §4.3.1 motivation");
    finding("at skew 1.0 (uniform lengths) the two schemes tie: the hybrid's extra collectives are cheap on UB");
}
