//! Fig. 23: prefill throughput and TTFT vs token reuse rate, EMS over UB
//! vs over VPC (§5.4.3) — exercised through the *real* mempool +
//! context-cache implementation plus the prefill timing model.

use cm_infer::benchlib::{finding, Table};
use cm_infer::cache::ContextCache;
use cm_infer::config::{Ascend910cDie, DeepSeekDims};
use cm_infer::mempool::MemPool;
use cm_infer::simnpu::pipeline::{prefill_model, PrefillPoint};

/// Per-cached-token handling cost as a fraction of a fully-computed token.
///
/// A cache hit skips the transformer stack but still pays block lookup,
/// fabric fetch, KV reinjection into the NPU's NZ-layout cache, and
/// scheduler bookkeeping. Calibrated against Fig 23's own anchors (tput
/// x1.42 going 12.5%→50% reuse, x2.28 at 90%; TTFT −34%/−59%): the UB
/// path lands at ~1/3 of a computed token, the VPC path at ~0.55 (slower
/// fabric dominates block fetch).
const REINJECT_FRAC_UB: f64 = 0.33;
const REINJECT_FRAC_VPC: f64 = 0.55;

/// Model one prefill with `reuse` of the 4K prompt served from cache over
/// the given fabric; returns (throughput tokens/s/NPU, TTFT ms).
fn point(
    die: &Ascend910cDie,
    m: &DeepSeekDims,
    pool: &mut MemPool,
    cc: &mut ContextCache,
    reuse_rate: f64,
    prompt: usize,
) -> (f64, f64) {
    let reused = (prompt as f64 * reuse_rate) as usize;
    let computed = prompt - reused;
    let over_ub = cc.over_ub;

    // fetch reused blocks through the real pool (charges UB or VPC)
    let tokens: Vec<i32> = (0..prompt as i32).collect();
    cc.store(pool, &tokens[..reused.max(1)]);
    let hit = cc.lookup(pool, &tokens[..reused.max(1)]);
    let fetch_us = hit.fetch_us;

    // effective compute: suffix tokens at full cost + cached tokens at the
    // reinjection fraction (same per-NPU batch of 16K prompt tokens)
    let frac = if over_ub { REINJECT_FRAC_UB } else { REINJECT_FRAC_VPC };
    let effective = computed as f64 + reused as f64 * frac;
    let pf = prefill_model(
        die,
        m,
        &PrefillPoint {
            prompt_len: prompt,
            tokens_per_npu: ((16384.0 * effective / prompt as f64) as usize).max(1),
            ..PrefillPoint::paper_reference(false)
        },
    );
    let batch_us = pf.batch_us + fetch_us;
    let tput = 16384.0 / (batch_us / 1e6); // prompt tokens served
    let ttft_ms = (batch_us / 16.0) / 1000.0 * 4.0; // per-request share
    (tput, ttft_ms)
}

fn main() {
    let die = Ascend910cDie::default();
    let m = DeepSeekDims::deepseek_r1();

    let mut t = Table::new(
        "Fig 23 — EMS context caching: reuse rate vs prefill throughput & TTFT",
        &["Reuse rate", "tok/s/NPU (UB)", "tok/s/NPU (VPC)", "UB/VPC", "TTFT ms (UB)",
          "TTFT ms (VPC)"],
    );
    let mut base_tput = 0.0;
    let mut results = Vec::new();
    for reuse in [0.0, 0.125, 0.25, 0.5, 0.75, 0.9] {
        let mut pool_ub = MemPool::new(8, 8 << 30, 32 << 30);
        let mut cc_ub = ContextCache::new(&mut pool_ub, 256, m.kv_bytes_per_token(), true);
        let mut pool_vpc = MemPool::new(8, 8 << 30, 32 << 30);
        let mut cc_vpc = ContextCache::new(&mut pool_vpc, 256, m.kv_bytes_per_token(), false);
        let (tput_ub, ttft_ub) = point(&die, &m, &mut pool_ub, &mut cc_ub, reuse, 4096);
        let (tput_vpc, ttft_vpc) = point(&die, &m, &mut pool_vpc, &mut cc_vpc, reuse, 4096);
        if reuse == 0.0 {
            base_tput = tput_ub;
        }
        t.row(&[
            format!("{:.1}%", reuse * 100.0),
            format!("{tput_ub:.0}"),
            format!("{tput_vpc:.0}"),
            format!("{:.2}x", tput_ub / tput_vpc),
            format!("{ttft_ub:.0}"),
            format!("{ttft_vpc:.0}"),
        ]);
        results.push((reuse, tput_ub, tput_vpc));
    }
    t.print();

    let at_90 = results.last().unwrap();
    finding(&format!(
        "paper shape: throughput x2.28 at 90% reuse (model: x{:.2}); UB beats VPC up to x1.52 (model max: x{:.2})",
        at_90.1 / base_tput,
        results.iter().map(|r| r.1 / r.2).fold(0.0f64, f64::max)
    ));
    finding("TTFT drops steeply with reuse rate (paper: -34% at 50%, -59% at 90%)");
}
