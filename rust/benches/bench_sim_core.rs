//! BENCH_sim_core — the sim-core perf-trajectory harness.
//!
//! Runs `ServeSim` on one pinned mega-scenario (`mixed_slo`, seed 42,
//! 1 M requests, 8-instance decode pool, frozen split, no chaos) and
//! measures *events dispatched per wall-clock second* — the metric the
//! event-loop split and the hot-path index work are judged against. The
//! scenario is run twice: the second run both sharpens the timing (best
//! of two) and pins same-seed determinism at mega size — the report
//! scalars and the event count must be bit-identical across runs.
//!
//! Emits `BENCH_sim_core.json` at the repo root (CI uploads it as the
//! perf-trajectory artifact; `rust/tests/perf_smoke.rs` gates a
//! scaled-down variant of the same scenario against a committed
//! baseline). `CM_BENCH_QUICK=1` drops to 50 K requests for smoke runs.

use std::collections::BTreeMap;
use std::time::Instant;

use cm_infer::benchlib::{finding, quick, Table};
use cm_infer::config::Config;
use cm_infer::coordinator::sim::{ServeSim, SimOptions};
use cm_infer::util::json::Json;
use cm_infer::workload::{generate_scenario, ScenarioSpec};

const SEED: u64 = 42;
const OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_sim_core.json");

/// FNV-1a fold over the IEEE-754 bit patterns of the report scalars that
/// the golden-trace harness also pins — any arithmetic drift between the
/// two runs (or across seeds) changes this digest.
fn report_digest(r: &cm_infer::metrics::ServingReport) -> u64 {
    let scalars = [
        r.duration_us,
        r.requests_completed as f64,
        r.prompt_tokens as f64,
        r.output_tokens as f64,
        r.goodput_tokens as f64,
        r.ttft_us.p50,
        r.ttft_us.p99,
        r.tpot_us.p50,
        r.tpot_us.p99,
        r.requests_lost as f64,
    ];
    scalars.iter().fold(0xcbf2_9ce4_8422_2325u64, |h, v| {
        (h ^ v.to_bits()).wrapping_mul(0x0000_0100_0000_01b3)
    })
}

fn main() {
    let n: usize = if quick() { 50_000 } else { 1_000_000 };
    let sc = ScenarioSpec::by_name("mixed_slo", SEED).unwrap();
    let trace = generate_scenario(&sc, n);
    let mut cfg = Config::default();
    cfg.serving.tier_slos = sc.tier_slo_configs();
    let opts = SimOptions {
        seed: SEED,
        decode_instances: 8,
        max_events: usize::MAX,
        ..SimOptions::default()
    };

    let mut elapsed = Vec::new();
    let mut runs = Vec::new();
    for _ in 0..2 {
        let mut sim = ServeSim::new(cfg.clone(), opts.clone(), trace.clone());
        let t0 = Instant::now();
        let r = sim.run();
        elapsed.push(t0.elapsed().as_secs_f64());
        runs.push((sim.events_processed(), report_digest(&r), r));
    }
    assert_eq!(
        runs[0].0, runs[1].0,
        "same seed, different event count: the sim core is non-deterministic"
    );
    assert_eq!(
        runs[0].1, runs[1].1,
        "same seed, different report digest at mega size: f64 accumulation drifted"
    );

    let events = runs[0].0;
    let best = elapsed.iter().copied().fold(f64::INFINITY, f64::min);
    let events_per_sec = events as f64 / best;
    let r = &runs[0].2;

    let mut t = Table::new(
        "Sim-core event-loop throughput — mixed_slo mega-scenario",
        &["requests", "events", "best wall s", "events/s", "completed", "digest"],
    );
    t.row(&[
        format!("{n}"),
        format!("{events}"),
        format!("{best:.3}"),
        format!("{events_per_sec:.0}"),
        format!("{}", r.requests_completed),
        format!("{:#018x}", runs[0].1),
    ]);
    t.print();
    finding("per-event work is independent of deployment size: placement taxes, UB home planes, tier caps, and live-instance sets are indexed at layout time, and degradation lookups exit in O(1) when no window is active");

    let mut obj = BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str("sim_core".to_string()));
    obj.insert("scenario".to_string(), Json::Str("mixed_slo".to_string()));
    obj.insert("seed".to_string(), Json::Num(SEED as f64));
    obj.insert("requests".to_string(), Json::Num(n as f64));
    obj.insert("events".to_string(), Json::Num(events as f64));
    obj.insert("elapsed_s".to_string(), Json::Num(best));
    obj.insert("events_per_sec".to_string(), Json::Num(events_per_sec));
    obj.insert("digest".to_string(), Json::Str(format!("{:#018x}", runs[0].1)));
    obj.insert("quick".to_string(), Json::Bool(quick()));
    let doc = Json::Obj(obj).to_string();
    match std::fs::write(OUT, &doc) {
        Ok(()) => println!("  -> wrote {OUT}"),
        Err(e) => eprintln!("  -> could not write {OUT}: {e}"),
    }
}
