//! Table 3: prefill throughput per accelerator (default vs perfect EPLB)
//! vs the published DeepSeek-H800 and SGLang-H100 baselines.

use cm_infer::benchlib::{bench, finding, iters, Table};
use cm_infer::config::{Ascend910cDie, DeepSeekDims};
use cm_infer::simnpu::pipeline::{prefill_model, PrefillPoint};

fn main() {
    let die = Ascend910cDie::default();
    let m = DeepSeekDims::deepseek_r1();
    let npu_tflops = die.int8_tops * 2.0; // 1,504 INT8 per NPU

    // published baselines quoted by the paper (Table 3)
    let published: [(&str, f64, f64); 4] = [
        ("DeepSeek on H800 (Blog)", 4026.0, 1979.0),
        ("SGLang on H100 (Default)", 6288.0, 1979.0),
        ("DeepSeek on H800 (Profile)", 7839.0, 1979.0),
        ("SGLang on H100 (Perfect EPLB)", 7417.0, 1979.0),
    ];

    let default = prefill_model(&die, &m, &PrefillPoint::paper_reference(false));
    let perfect = prefill_model(&die, &m, &PrefillPoint::paper_reference(true));

    let mut t = Table::new(
        "Table 3 — prefill throughput per accelerator (4K prompts, 16K tok/NPU)",
        &["Method", "TFLOPS", "tokens/s", "tokens/s/TFLOPS"],
    );
    for (name, tput, tflops) in published {
        t.row(&[name.into(), format!("{tflops:.0} (FP8)"), format!("{tput:.0}"),
                format!("{:.2}", tput / tflops)]);
    }
    t.row(&[
        "CloudMatrix-Infer (Default) [model]".into(),
        format!("{npu_tflops:.0} (INT8)"),
        format!("{:.0}", default.tokens_per_s_per_npu),
        format!("{:.2}", default.tokens_per_s_per_tflops),
    ]);
    t.row(&[
        "CloudMatrix-Infer (Perfect EPLB) [model]".into(),
        format!("{npu_tflops:.0} (INT8)"),
        format!("{:.0}", perfect.tokens_per_s_per_npu),
        format!("{:.2}", perfect.tokens_per_s_per_tflops),
    ]);
    t.print();
    finding("paper: 5,655 default / 6,688 perfect-EPLB tokens/s per NPU → 3.76 / 4.45 tok/s/TFLOPS, beating all published baselines on efficiency");
    finding(&format!(
        "model: {:.0} / {:.0} tokens/s per NPU → {:.2} / {:.2} tok/s/TFLOPS",
        default.tokens_per_s_per_npu,
        perfect.tokens_per_s_per_npu,
        default.tokens_per_s_per_tflops,
        perfect.tokens_per_s_per_tflops
    ));

    let st = bench(10, iters(50_000), || {
        let v = prefill_model(&die, &m, &PrefillPoint::paper_reference(false));
        cm_infer::benchlib::black_box(v.tokens_per_s_per_npu);
    });
    println!("\nprefill-model eval: mean {:.2} µs", st.mean_us);
}
