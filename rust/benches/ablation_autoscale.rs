//! Ablation (paper §4.1 dynamic adjustment + §6.2.1 attention offloading):
//! the PD-ratio autoscaler's splits across workload mixes, and the
//! Adrenaline-style decode-attention offload frontier.

use cm_infer::benchlib::{finding, Table};
use cm_infer::config::{Ascend910cDie, Config, DeepSeekDims, ServingConfig};
use cm_infer::coordinator::autoscale::{offload, Autoscaler, WorkloadStats};
use cm_infer::coordinator::sim::{AutoscaleOptions, ServeSim, SimOptions};
use cm_infer::simnpu::pipeline::DecodePoint;
use cm_infer::workload::{generate_scenario, ScenarioSpec};

fn main() {
    let die = Ascend910cDie::default();
    let m = DeepSeekDims::deepseek_r1();
    let s = ServingConfig::paper_default();
    let a = Autoscaler::paper_default();

    // --- PD-ratio adaptation across workload mixes -------------------------
    let mut t = Table::new(
        "Dynamic PDC adjustment — recommended NPU split vs workload mix",
        &["Workload (prompt:output token rate)", "prefill NPUs", "decode NPUs",
          "prefill cap (tok/s)", "decode cap (tok/s)"],
    );
    for (name, prompt, output) in [
        ("chat, short prompts (1:2)", 500_000u64, 1_000_000u64),
        ("balanced (2:1)", 1_000_000, 500_000),
        ("RAG, long prompts (10:1)", 2_000_000, 200_000),
        ("summarization bursts (30:1)", 3_000_000, 100_000),
    ] {
        let stats = WorkloadStats {
            prompt_tokens: prompt,
            output_tokens: output,
            prefill_queue_tokens: 0.0,
            decode_occupancy: 0.8,
            window_us: 1e6,
        };
        match a.recommend(&die, &m, &s, &stats, 96) {
            Some(p) => t.row(&[
                name.into(),
                format!("{}", p.prefill_npus),
                format!("{}", p.decode_npus),
                format!("{:.0}", p.prefill_capacity),
                format!("{:.0}", p.decode_capacity),
            ]),
            None => t.row(&[name.into(), "96 (hold)".into(), "160 (hold)".into(),
                            "-".into(), "-".into()]),
        }
    }
    t.print();
    finding("the paper's §4.1 claim: longer prompts shift NPUs toward prefill, longer outputs toward decode — the controller reproduces both directions with instance-quantized, hysteresis-damped moves");

    // --- §6.2.1 attention offload frontier ---------------------------------
    let p = DecodePoint::paper_reference();
    let mut t = Table::new(
        "Attention offloading (Adrenaline-style, §6.2.1) — decode gains vs prefill cost",
        &["offload frac", "decode tok/s/NPU", "TPOT ms", "prefill retained"],
    );
    for i in 0..=5 {
        let frac = i as f64 * 0.2;
        let o = offload::model_offload(&die, &m, &p, frac);
        t.row(&[
            format!("{frac:.1}"),
            format!("{:.0}", o.tokens_per_s_per_npu),
            format!("{:.1}", o.tpot_ms),
            format!("{:.0}%", o.prefill_retained * 100.0),
        ]);
    }
    t.print();
    finding("offloading the memory-bound FA core raises decode throughput until the remote share + UB sync matches the local share — an interior optimum, as the Adrenaline paper reports");

    // --- §6.2.1 offload in the serving loop: three-way ablation ------------
    // The memory_bound_decode scenario on a decode-pressured 32-NPU slice:
    // frozen split vs elastic with the offload action vs elastic resplit-only.
    let sc = ScenarioSpec::memory_bound_decode(7);
    let n = 1000;
    let trace = generate_scenario(&sc, n);
    let mut cfg = Config::default();
    cfg.serving.decode_npus = 32;
    let mut t = Table::new(
        "Attention offload in ServeSim — memory_bound_decode, 96P/32D slice",
        &["leg", "decode tok/s/NPU", "TPOT p99 ms", "TTFT p99 ms",
          "SLO attainment", "engagements", "resplits"],
    );
    for (label, autoscale, offload_on) in [
        ("frozen", false, false),
        ("elastic + offload", true, true),
        ("elastic --no-offload", true, false),
    ] {
        let opts = SimOptions {
            seed: 7,
            autoscale: autoscale
                .then(|| AutoscaleOptions { offload: offload_on, ..AutoscaleOptions::default() }),
            ..SimOptions::default()
        };
        let r = ServeSim::new(cfg.clone(), opts, trace.clone()).run();
        t.row(&[
            label.into(),
            format!("{:.0}", r.decode_tokens_per_s_per_npu()),
            format!("{:.1}", r.tpot_us.p99 / 1e3),
            format!("{:.0}", r.ttft_us.p99 / 1e3),
            format!("{:.1}%", r.overall_attainment() * 100.0),
            format!("{}", r.offload_engagements()),
            format!("{}", r.resplits.len()),
        ]);
    }
    t.print();
    finding("in the memory-bound decode regime the controller answers pressure by borrowing idle prefill HBM bandwidth (offload engagements, zero role switches) instead of paying the Table-2 warm-switch latency a resplit costs");
}
