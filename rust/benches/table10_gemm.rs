//! Table 10: INT8 GEMM performance + achieved memory bandwidth on one
//! Ascend 910C die, plus wallclock of the *real* Pallas kernel path (the
//! int8 GEMM inside the AOT-compiled decode graph is timed by hotpath_l3).

use cm_infer::benchlib::{bench, finding, iters, Table};
use cm_infer::config::Ascend910cDie;
use cm_infer::simnpu::ops::gemm::{table10_shapes, time_int8};

fn main() {
    let die = Ascend910cDie::default();
    let paper = [
        (597.0, 79.4, 260.0),
        (582.0, 77.4, 325.0),
        (622.0, 82.7, 195.0),
        (610.0, 81.1, 266.0),
        (599.0, 79.6, 261.0),
        (586.0, 77.9, 327.0),
    ];

    let mut t = Table::new(
        "Table 10 — INT8 GEMM on one 910C die (INT8 in, BF16 out)",
        &["Groups", "M", "N", "K", "TFLOPS [model/paper]",
          "Util % [model/paper]", "Mem GB/s [model/paper]"],
    );
    for (shape, (p_tf, p_util, p_bw)) in table10_shapes().iter().zip(paper) {
        let r = time_int8(&die, shape);
        t.row(&[
            format!("{}", shape.groups),
            format!("{}", shape.m),
            format!("{}", shape.n),
            format!("{}", shape.k),
            format!("{:.0} / {:.0}", r.achieved_tflops, p_tf),
            format!("{:.1} / {:.1}", r.utilization * 100.0, p_util),
            format!("{:.0} / {:.0}", r.memory_gbps, p_bw),
        ]);
    }
    t.print();
    finding("paper shape: 77–83% compute utilization, memory BW far below the 1.6 TB/s peak → compute-bound with good data reuse (§5.5.3)");

    let shapes = table10_shapes();
    let st = bench(10, iters(200_000), || {
        for s in &shapes {
            cm_infer::benchlib::black_box(time_int8(&die, s).time_us);
        }
    });
    println!("\ngemm-model eval (6 shapes): mean {:.3} µs", st.mean_us);
}
