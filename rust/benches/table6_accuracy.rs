//! Table 6 (substituted): INT8 quantization fidelity of the *real* model.
//!
//! The paper compares DeepSeek-R1 INT8 against the official API on 16
//! benchmarks. At our scale the transferable claim is *quantization
//! fidelity*: the §4.5-quantized model's outputs match the float model's.
//! This bench reads the per-layer fidelity report produced at AOT time
//! (python/compile/quant.py) and, when artifacts exist, compares fp-vs-int8
//! logits of the real model through PJRT.

use cm_infer::benchlib::{finding, Table};
use cm_infer::runtime::{ModelRuntime, Variant};
use cm_infer::util::Json;

fn main() {
    let dir = std::env::var("CM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let manifest_path = format!("{dir}/manifest.json");
    let Ok(text) = std::fs::read_to_string(&manifest_path) else {
        println!("(artifacts not built — run `make artifacts`; skipping)");
        return;
    };
    let j = Json::parse(&text).expect("manifest parses");

    // --- per-layer offline fidelity report (quant.py, Eq. 3/4 pipeline) ---
    let mut t = Table::new(
        "Table 6 (substituted) — INT8 quantization fidelity per layer class",
        &["Layer", "rel error", "SNR (dB)"],
    );
    let mut worst = ("-".to_string(), 0.0f64);
    if let Some(fid) = j.get("quant_fidelity").and_then(|f| f.as_obj().ok()) {
        let mut shown = 0;
        for (name, rep) in fid {
            let rel = rep.get("rel_error").and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
            let snr = rep.get("snr_db").and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
            if rel > worst.1 {
                worst = (name.clone(), rel);
            }
            if shown < 12 {
                t.row(&[name.clone(), format!("{rel:.4}"), format!("{snr:.1}")]);
                shown += 1;
            }
        }
        if fid.len() > 12 {
            t.row(&[format!("... ({} layers total)", fid.len()), "".into(), "".into()]);
        }
    }
    t.print();
    finding(&format!("worst-layer relative error: {} = {:.4}", worst.0, worst.1));

    // --- end-to-end: fp vs int8 logits through PJRT ------------------------
    println!("\ncomparing fp vs int8 model outputs through PJRT (this compiles two runtimes)...");
    let rt_fp = match ModelRuntime::load(&dir, Variant::Fp) {
        Ok(r) => r,
        Err(e) => {
            println!("(fp runtime unavailable: {e}; skipping end-to-end check)");
            return;
        }
    };
    let rt_q = match ModelRuntime::load(&dir, Variant::Int8) {
        Ok(r) => r,
        Err(e) => {
            println!("(int8 runtime unavailable: {e}; skipping end-to-end check)");
            return;
        }
    };
    let v = rt_fp.manifest.model.vocab_size;
    let mut top1_agree = 0usize;
    let mut total = 0usize;
    let mut mse = 0.0f64;
    for seed in 0..8 {
        let prompt: Vec<i32> = (0..48).map(|i| ((i * 997 + seed * 131 + 7) % v) as i32).collect();
        let a = rt_fp.prefill(&prompt).expect("fp prefill");
        let b = rt_q.prefill(&prompt).expect("int8 prefill");
        let am = argmax(&a.logits);
        let bm = argmax(&b.logits);
        top1_agree += (am == bm) as usize;
        total += 1;
        mse += a
            .logits
            .iter()
            .zip(&b.logits)
            .map(|(x, y)| (x - y) as f64 * (x - y) as f64)
            .sum::<f64>()
            / a.logits.len() as f64;
    }
    println!(
        "top-1 agreement fp vs int8: {top1_agree}/{total}; mean logit MSE {:.5}",
        mse / total as f64
    );
    finding("paper shape: INT8 accuracy comparable to the full-precision reference across all benchmarks (Table 6)");
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap()
}
