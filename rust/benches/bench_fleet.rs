//! BENCH_fleet — fleet-scale serving: goodput rate vs pod count and
//! admission-routing policy.
//!
//! Sweeps the `fleet_diurnal` scenario (session chat under a diurnal
//! wave, one pod drained for maintenance at the traffic peak) across
//! supernode counts {1, 2, 4}, prefix-affinity admission routing vs the
//! stateless least-loaded ablation. The headline columns are fleet
//! goodput tok/s (useful tokens over the makespan) against the cross-pod
//! RDMA import and forced re-prefill counts — the cost the affinity
//! router avoids paying.
//!
//! Emits `BENCH_fleet.json` at the repo root (CI uploads it alongside
//! `BENCH_session.json`). `CM_BENCH_QUICK=1` drops to 2 K requests.

use std::collections::BTreeMap;

use cm_infer::benchlib::{finding, quick, Table};
use cm_infer::config::Config;
use cm_infer::coordinator::sim::SimOptions;
use cm_infer::faults::PodDrainPlan;
use cm_infer::fleet::{FleetOptions, FleetSim};
use cm_infer::util::json::Json;
use cm_infer::workload::{generate_scenario, ScenarioSpec};

const SEED: u64 = 42;
const OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_fleet.json");

struct LegOut {
    leg: String,
    pods: usize,
    affinity: bool,
    goodput_tok_s: f64,
    makespan_s: f64,
    attainment: f64,
    moved_sessions: u64,
    rdma_imports: u64,
    rdma_import_tokens: u64,
    forced_reprefills: u64,
}

fn run_leg(pods: usize, affinity: bool, n: usize) -> LegOut {
    let sc = ScenarioSpec::by_name("fleet_diurnal", SEED).unwrap();
    let trace = generate_scenario(&sc, n);
    let mut cfg = Config::default();
    cfg.serving.tier_slos = sc.tier_slo_configs();
    let opts = SimOptions { seed: SEED, ..SimOptions::default() };
    let period = sc.wave.as_ref().map(|w| w.period_us).unwrap();
    let fleet = FleetOptions {
        supernodes: pods,
        affinity,
        drains: PodDrainPlan::maintenance_at_peak(pods, period),
    };
    let run = FleetSim::new(cfg, opts, fleet).run(trace);
    let r = &run.report;
    assert_eq!(r.requests_completed(), n as u64, "pods={pods}: dropped requests");
    LegOut {
        leg: format!("{}pod_{}", pods, if affinity { "affinity" } else { "least_loaded" }),
        pods,
        affinity,
        goodput_tok_s: r.goodput_tokens_per_s(),
        makespan_s: r.makespan_us() / 1e6,
        attainment: r.overall_attainment(),
        moved_sessions: r.moved_sessions,
        rdma_imports: r.xpod_imports,
        rdma_import_tokens: r.xpod_import_tokens,
        forced_reprefills: r.forced_reprefills,
    }
}

fn main() {
    let n: usize = if quick() { 2_000 } else { 20_000 };

    let mut legs = Vec::new();
    for pods in [1usize, 2, 4] {
        legs.push(run_leg(pods, true, n));
        if pods > 1 {
            legs.push(run_leg(pods, false, n));
        }
    }

    let mut t = Table::new(
        "Fleet-scale serving — goodput tok/s vs pod count and admission routing",
        &[
            "leg",
            "pods",
            "routing",
            "goodput tok/s",
            "makespan s",
            "attain",
            "moved",
            "rdma imports",
            "forced reprefill",
        ],
    );
    for l in &legs {
        t.row(&[
            l.leg.clone(),
            l.pods.to_string(),
            if l.affinity { "affinity" } else { "least-loaded" }.to_string(),
            format!("{:.0}", l.goodput_tok_s),
            format!("{:.2}", l.makespan_s),
            format!("{:.3}", l.attainment),
            l.moved_sessions.to_string(),
            l.rdma_imports.to_string(),
            l.forced_reprefills.to_string(),
        ]);
    }
    t.print();
    finding("fleet affinity routing keeps sessions on the pod holding their cached prefix: at every multi-pod point it beats least-loaded admission on goodput tok/s, paying a handful of RDMA prefix imports instead of the ablation's full re-prefill on every cross-pod session move");

    let rows: Vec<Json> = legs
        .iter()
        .map(|l| {
            let mut o = BTreeMap::new();
            o.insert("leg".to_string(), Json::Str(l.leg.clone()));
            o.insert("pods".to_string(), Json::Num(l.pods as f64));
            o.insert("affinity".to_string(), Json::Bool(l.affinity));
            o.insert("goodput_tok_s".to_string(), Json::Num(l.goodput_tok_s));
            o.insert("makespan_s".to_string(), Json::Num(l.makespan_s));
            o.insert("attainment".to_string(), Json::Num(l.attainment));
            o.insert("moved_sessions".to_string(), Json::Num(l.moved_sessions as f64));
            o.insert("rdma_imports".to_string(), Json::Num(l.rdma_imports as f64));
            o.insert(
                "rdma_import_tokens".to_string(),
                Json::Num(l.rdma_import_tokens as f64),
            );
            o.insert(
                "forced_reprefills".to_string(),
                Json::Num(l.forced_reprefills as f64),
            );
            Json::Obj(o)
        })
        .collect();
    let mut obj = BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str("fleet".to_string()));
    obj.insert("seed".to_string(), Json::Num(SEED as f64));
    obj.insert("requests".to_string(), Json::Num(n as f64));
    obj.insert("legs".to_string(), Json::Arr(rows));
    obj.insert("quick".to_string(), Json::Bool(quick()));
    let doc = Json::Obj(obj).to_string();
    match std::fs::write(OUT, &doc) {
        Ok(()) => println!("  -> wrote {OUT}"),
        Err(e) => eprintln!("  -> could not write {OUT}: {e}"),
    }
}
