//! Table 9: MLA operator memory-bandwidth utilization, memory-bound regime.

use cm_infer::benchlib::{finding, Table};
use cm_infer::config::{Ascend910cDie, DeepSeekDims};
use cm_infer::simnpu::ops::mla;

fn main() {
    let die = Ascend910cDie::default();

    let mut t = Table::new(
        "Table 9 — MLA memory bandwidth utilization (memory-intensive)",
        &["Implementation", "Achieved GB/s", "Peak GB/s", "Utilization"],
    );
    t.row(&[
        "DeepSeek FlashMLA on H800".into(),
        format!("{:.0}", mla::h800::ACHIEVED_GBPS),
        format!("{:.0}", mla::h800::PEAK_GBPS),
        format!("{:.1}%", mla::h800::memory_util() * 100.0),
    ]);
    t.row(&[
        "CANN MLA on Ascend 910C die [model]".into(),
        format!("{:.0}", mla::memory_bound_gbps(&die)),
        format!("{:.0}", die.hbm_gbps),
        format!("{:.1}%", die.mla_memory_util * 100.0),
    ]);
    t.print();
    finding("paper shape: both implementations run close to their HBM roofline (89.6% vs 84.1%) — decode MLA is fundamentally a cache-streaming workload");

    // derived: decode-style memory-bound MLA sweep over KV length
    let m = DeepSeekDims::deepseek_r1();
    println!("\ndecode MLA core time vs KV length (batch 48/die):");
    for kv in [1024usize, 2048, 4096, 8192, 16384] {
        let shape = mla::MlaDecodeShape { batch: 48, q_tokens: 1, kv_len: kv };
        let (_p, core, _o) = mla::decode_mla_us(&die, &m, &shape, 1.0, true);
        let bytes = mla::attn_core_bytes(&m, &shape) / 1e6;
        println!("  kv {kv:6}: core {core:7.0} µs  ({bytes:.0} MB latent cache read)");
    }
}
