//! Table 7: Dispatch/Combine latency + per-rank bandwidth vs EP degree —
//! CANN EP on CloudMatrix384 (UB) vs DeepSeek DeepEP on H800 (RDMA).

use cm_infer::benchlib::{bench, finding, iters, Table};
use cm_infer::config::Ascend910cDie;
use cm_infer::simnpu::ops::comm::{collective, table7_eps, CommImpl, CommPhase};

fn main() {
    let die = Ascend910cDie::default();
    for (phase, pname) in [(CommPhase::Dispatch, "Dispatch"), (CommPhase::Combine, "Combine")] {
        let mut t = Table::new(
            &format!("Table 7 — {pname} (batch 128/rank, top-8)"),
            &["#EP", "H800 DeepEP lat (µs)", "H800 BW (GB/s)",
              "CM384 CANN lat (µs)", "CM384 BW (GB/s)", "speedup"],
        );
        for ep in table7_eps() {
            let h = collective(&die, CommImpl::H800DeepEp, phase, ep, 128, 8, true);
            let c = collective(&die, CommImpl::Cm384CannEp, phase, ep, 128, 8, true);
            t.row(&[
                format!("{ep}"),
                format!("{:.0}", h.latency_us),
                format!("{:.0}", h.bandwidth_gbps),
                format!("{:.0}", c.latency_us),
                format!("{:.0}", c.bandwidth_gbps),
                format!("{:.2}x", h.latency_us / c.latency_us),
            ]);
        }
        t.print();
    }
    finding("paper shape: CM384 dispatch ~1.3x faster, combine ~2.4–2.7x faster than H800 DeepEP at every EP degree; CM384 bandwidth declines at large EP (the noted scalability bottleneck)");

    let st = bench(10, iters(100_000), || {
        let c = collective(&die, CommImpl::Cm384CannEp, CommPhase::Dispatch, 320, 96, 8, true);
        cm_infer::benchlib::black_box(c.latency_us);
    });
    println!("\ncollective-model eval: mean {:.3} µs", st.mean_us);
}
