//! Ablation (§4.2.1 Opt.1): AIV-direct writes vs the SDMA path for MoE
//! dispatch/combine, across decode-relevant batch sizes at EP320.

use cm_infer::benchlib::{finding, Table};
use cm_infer::config::{Ascend910cDie, DeepSeekDims};
use cm_infer::simnpu::ops::comm::{collective, CommImpl, CommPhase};
use cm_infer::simnpu::pipeline::{decode_step, DecodePoint};

fn main() {
    let die = Ascend910cDie::default();
    let m = DeepSeekDims::deepseek_r1();

    let mut t = Table::new(
        "Ablation — AIV-direct vs SDMA dispatch at EP320 (per collective)",
        &["Batch/rank", "AIV-direct µs", "SDMA µs", "penalty"],
    );
    for batch in [8usize, 24, 48, 96] {
        let aiv = collective(&die, CommImpl::Cm384CannEp, CommPhase::Dispatch, 320, batch, m.top_k, true);
        let sdma = collective(&die, CommImpl::Cm384Sdma, CommPhase::Dispatch, 320, batch, m.top_k, true);
        t.row(&[
            format!("{batch}"),
            format!("{:.0}", aiv.latency_us),
            format!("{:.0}", sdma.latency_us),
            format!("+{:.0}%", (sdma.latency_us / aiv.latency_us - 1.0) * 100.0),
        ]);
    }
    t.print();
    finding("the SDMA startup cost (~25 µs vs ~4 µs) dominates at decode's small per-step payloads — exactly why §4.2.1 builds AIV-direct");

    // end-to-end effect on decode TPOT: swap the dispatch/combine latency
    // by the SDMA-vs-AIV delta per layer
    let base = decode_step(&die, &m, &DecodePoint::paper_reference());
    let aiv = collective(&die, CommImpl::Cm384CannEp, CommPhase::Dispatch, 320, 48, m.top_k, true)
        .latency_us
        + collective(&die, CommImpl::Cm384CannEp, CommPhase::Combine, 320, 48, m.top_k, true)
            .latency_us;
    let sdma = collective(&die, CommImpl::Cm384Sdma, CommPhase::Dispatch, 320, 48, m.top_k, true)
        .latency_us
        + collective(&die, CommImpl::Cm384Sdma, CommPhase::Combine, 320, 48, m.top_k, true)
            .latency_us;
    let delta_per_layer = sdma - aiv;
    let sdma_step = base.step_us + delta_per_layer * m.n_layers as f64;
    println!(
        "\ndecode step: {:.1} ms (AIV-direct) vs {:.1} ms (SDMA) → TPOT {:.1} vs {:.1} ms",
        base.step_us / 1e3,
        sdma_step / 1e3,
        base.tpot_ms,
        sdma_step / 1.7 / 1e3
    );
}
