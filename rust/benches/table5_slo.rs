//! Table 5: decode throughput under different TPOT SLOs and prompt/output
//! lengths — the SLO-adaptive batching result.

use cm_infer::benchlib::{finding, Table};
use cm_infer::config::{Ascend910cDie, DeepSeekDims, SloConfig};
use cm_infer::coordinator::batcher::plan_for_slo;
use cm_infer::simnpu::pipeline::DecodePoint;

fn main() {
    let die = Ascend910cDie::default();
    let m = DeepSeekDims::deepseek_r1();

    // (slo_ms, prompt, output) rows from the paper
    let rows = [
        (50.0, 1024usize, 1024usize),
        (50.0, 2048, 256),
        (50.0, 4096, 256),
        (30.0, 4096, 256),
        (15.0, 4096, 256),
    ];
    let paper = [(128usize, 46.8, 2733.0), (112, 47.4, 2360.0), (96, 49.4, 1943.0),
                 (24, 24.6, 974.0), (8, 14.9, 538.0)];

    let mut t = Table::new(
        "Table 5 — decode throughput vs TPOT SLO and lengths",
        &["SLO (ms)", "Prompt", "Output", "Batch [model/paper]",
          "TPOT ms [model/paper]", "tok/s/NPU [model/paper]"],
    );
    for ((slo, prompt, output), (p_batch, p_tpot, p_tput)) in rows.iter().zip(paper) {
        // mean KV length over the decode = prompt + output/2
        let kv = prompt + output / 2;
        let base = DecodePoint { kv_len: kv, ..DecodePoint::paper_reference() };
        let plan = plan_for_slo(&die, &m, &base, &SloConfig { tpot_ms: *slo, ttft_ms: 1e9 }, 160);
        t.row(&[
            format!("{slo:.0}"),
            format!("{prompt}"),
            format!("{output}"),
            format!("{} / {}", plan.batch_per_npu, p_batch),
            format!("{:.1} / {:.1}", plan.predicted_tpot_ms, p_tpot),
            format!("{:.0} / {:.0}", plan.predicted_tput, p_tput),
        ]);
    }
    t.print();
    finding("paper shape: shorter contexts → bigger batches → higher throughput; tightening the SLO 50→15 ms trades throughput 1,943→538 tok/s/NPU");
    finding("model reproduces the monotone frontier; absolute numbers at small batch are conservative (scheduling-gap model, see EXPERIMENTS.md)");
}
