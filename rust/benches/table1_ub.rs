//! Table 1: UB intra- vs inter-node bandwidth/latency (NPU-NPU / NPU-CPU,
//! read/write). Regenerates the paper's table from the netsim parameters
//! and times the cost-model evaluation itself.

use cm_infer::benchlib::{bench, finding, iters, Table};
use cm_infer::netsim::{Locality, NetSim, OpKind, PathKind};

fn main() {
    let net = NetSim::default();
    let mut t = Table::new(
        "Table 1 — UB plane: intra vs inter-node (per die)",
        &["Path", "Op", "BW inter (GB/s)", "BW intra (GB/s)", "Ratio",
          "Lat inter (µs, 512B)", "Lat intra (µs, 512B)", "Ratio"],
    );
    for (path, pname) in [(PathKind::NpuToNpu, "NPU-NPU"), (PathKind::NpuToCpu, "NPU-CPU")] {
        for (op, oname) in [(OpKind::Read, "Read"), (OpKind::Write, "Write")] {
            let inter = net.ub_params(path, op, Locality::InterNode);
            let intra = net.ub_params(path, op, Locality::IntraNode);
            let lat_inter = inter.transfer_us(512) - 512.0 / (inter.bandwidth_gbps * 1e3);
            let lat_intra = intra.transfer_us(512) - 512.0 / (intra.bandwidth_gbps * 1e3);
            t.row(&[
                pname.into(),
                oname.into(),
                format!("{:.0}", inter.bandwidth_gbps),
                format!("{:.0}", intra.bandwidth_gbps),
                format!("{:.2}", inter.bandwidth_gbps / intra.bandwidth_gbps),
                format!("{:.1}", lat_inter),
                format!("{:.1}", lat_intra),
                format!("{:.2}", lat_inter / lat_intra),
            ]);
        }
    }
    t.print();
    finding("paper shape: inter-node bandwidth within 3% of intra; latency +<1 µs (§3.2)");

    // Cost-model hot path timing (used in every sim event)
    let st = bench(100, iters(100_000), || {
        let v = net.transfer_us(
            cm_infer::netsim::Plane::Ub,
            PathKind::NpuToNpu,
            OpKind::Read,
            Locality::InterNode,
            1 << 20,
        );
        cm_infer::benchlib::black_box(v);
    });
    println!("\ncost-model eval: mean {:.3} µs p99 {:.3} µs", st.mean_us, st.p99_us);
}
