//! Table 11: UB switch utilization across supernode scales (§6.1.2's
//! "nearly constant amortized network cost").

use cm_infer::benchlib::{finding, Table};
use cm_infer::config::CloudMatrixTopo;
use cm_infer::topology::switches::{chips_per_npu, switch_plan};

fn main() {
    let topo = CloudMatrixTopo::default();
    let paper = [(384usize, 48usize, 56usize, 100.0),
                 (352, 44, 56, 92.0),
                 (288, 36, 42, 100.0),
                 (256, 32, 42, 89.0),
                 (192, 24, 28, 100.0)];

    let mut t = Table::new(
        "Table 11 — switch utilization vs supernode scale",
        &["NPUs", "Nodes", "Switches [model/paper]", "Utilization [model/paper]",
          "chips/NPU (amortized)"],
    );
    for (npus, p_nodes, p_sw, p_util) in paper {
        let p = switch_plan(&topo, npus);
        assert_eq!(p.nodes, p_nodes);
        t.row(&[
            format!("{npus}"),
            format!("{}", p.nodes),
            format!("{} / {}", p.switches, p_sw),
            format!("{:.0}% / {:.0}%", p.utilization * 100.0, p_util),
            format!("{:.3}", chips_per_npu(&p)),
        ]);
    }
    t.print();
    finding("paper shape: 100% port utilization at 192/288/384 NPUs (full tiers), dips between; amortized chips/NPU constant at the full-utilization points → scaling supernodes costs nothing extra in network (§6.1.2)");
}
