//! Fig. 22: decode throughput and per-layer latency with and without MTP
//! (§4.2.4), plus the naive-vs-pipelined MTP dispatch comparison (Fig 15).

use cm_infer::benchlib::{finding, Table};
use cm_infer::config::{Ascend910cDie, DeepSeekDims};
use cm_infer::simnpu::pipeline::{decode_layer, decode_step, DecodePoint};

fn main() {
    let die = Ascend910cDie::default();
    let m = DeepSeekDims::deepseek_r1();

    let mut t = Table::new(
        "Fig 22a — decode throughput w/ and w/o MTP (4K KV, accept 0.70)",
        &["Batch/NPU", "tok/s/NPU (off)", "tok/s/NPU (on)", "gain"],
    );
    for batch in [16usize, 32, 64, 96, 128] {
        let on = decode_step(&die, &m, &DecodePoint {
            batch_per_npu: batch, ..DecodePoint::paper_reference()
        });
        let off = decode_step(&die, &m, &DecodePoint {
            batch_per_npu: batch, mtp: false, ..DecodePoint::paper_reference()
        });
        t.row(&[
            format!("{batch}"),
            format!("{:.0}", off.tokens_per_s_per_npu),
            format!("{:.0}", on.tokens_per_s_per_npu),
            format!("+{:.0}%", (on.tokens_per_s_per_npu / off.tokens_per_s_per_npu - 1.0) * 100.0),
        ]);
    }
    t.print();
    finding("paper shape: +6–49% throughput, larger at small batch (fixed overheads amortize); model reproduces the monotone-decreasing gain");

    let on = decode_layer(&die, &m, &DecodePoint::paper_reference());
    let off = decode_layer(&die, &m, &DecodePoint { mtp: false, ..DecodePoint::paper_reference() });
    println!(
        "\nFig 22b — per-layer latency at batch 96: {:.0} µs (no MTP) → {:.0} µs (MTP, +{:.0}%)",
        off.layer,
        on.layer,
        (on.layer / off.layer - 1.0) * 100.0
    );
    finding("paper: 874 → 1,260 µs (+44%) — each MTP step processes 2 tokens/request, but 1.7 accepted tokens/step outweigh the longer iteration");

    // Fig 15: naive MTP pays (k+1) graph dispatches of 0.6–0.8 ms per step
    let k = 1.0;
    let naive_overhead_us = (k + 1.0) * die.graph_dispatch_us;
    let step = decode_step(&die, &m, &DecodePoint::paper_reference());
    let naive_step = step.step_us + naive_overhead_us;
    let mut t = Table::new(
        "Fig 15 — naive vs pipelined MTP execution (batch 96)",
        &["Variant", "step µs", "TPOT ms", "tok/s/NPU"],
    );
    let accepted = 1.7;
    t.row(&[
        "naive (CPU-dispatched graphs)".into(),
        format!("{:.0}", naive_step),
        format!("{:.1}", naive_step / accepted / 1000.0),
        format!("{:.0}", 96.0 * accepted / (naive_step / 1e6)),
    ]);
    t.row(&[
        "pipelined (aggregated metadata + in-NPU sampling)".into(),
        format!("{:.0}", step.step_us),
        format!("{:.1}", step.tpot_ms),
        format!("{:.0}", step.tokens_per_s_per_npu),
    ]);
    t.print();
    finding("paper shape: removing per-graph CPU dispatch (0.6–0.8 ms x k+1 graphs) keeps the NPU busy end-to-end (§4.2.4)");
}
