//! L3 hot-path microbenchmarks (the §Perf deliverable): the operations the
//! coordinator executes per request/step — routing, batch formation,
//! admission, mempool put/get, context-cache key chaining, decode-step
//! bookkeeping — plus the end-to-end sim event rate.

use cm_infer::benchlib::{bench, iters, Table};
use cm_infer::cache::ContextCache;
use cm_infer::config::Config;
use cm_infer::coordinator::decode::DecodeInstance;
use cm_infer::coordinator::router::{Router, RouterKind};
use cm_infer::coordinator::sim::{ServeSim, SimOptions};
use cm_infer::mempool::{Key, MemPool};
use cm_infer::workload::{generate, WorkloadSpec};

fn main() {
    let mut t = Table::new(
        "L3 hot paths",
        &["Operation", "mean µs", "p99 µs", "ops/s"],
    );

    // router decision
    let mut router = Router::new(RouterKind::PeerToPeer, 6);
    let mut s = 0u64;
    let st = bench(1000, iters(1_000_000), || {
        s = s.wrapping_add(1);
        let d = router.route(s % 512, 4096).unwrap();
        router.complete(d.instance, 4096);
    });
    t.row(&["router route+complete".into(), format!("{:.3}", st.mean_us),
            format!("{:.3}", st.p99_us), format!("{:.2e}", 1e6 / st.mean_us)]);

    // mempool put/get
    let mut pool = MemPool::new(8, 4 << 30, 16 << 30);
    let ns = pool.controller.create_namespace("bench");
    let mut i = 0u64;
    let st = bench(1000, iters(300_000), || {
        i = i.wrapping_add(1);
        let k = Key::of_bytes(&i.to_le_bytes());
        pool.put(ns, k, 128 * 1024);
        cm_infer::benchlib::black_box(pool.get(ns, k, true));
    });
    t.row(&["mempool put+get (128 KiB)".into(), format!("{:.3}", st.mean_us),
            format!("{:.3}", st.p99_us), format!("{:.2e}", 1e6 / st.mean_us)]);

    // context-cache key chaining (per 4K-token prompt)
    let mut pool2 = MemPool::new(8, 4 << 30, 16 << 30);
    let cc = ContextCache::new(&mut pool2, 256, 1280, true);
    let prompt: Vec<i32> = (0..4096).collect();
    let st = bench(100, iters(50_000), || {
        cm_infer::benchlib::black_box(cc.block_keys(&prompt));
    });
    t.row(&["context-cache keys (4K prompt)".into(), format!("{:.3}", st.mean_us),
            format!("{:.3}", st.p99_us), format!("{:.2e}", 1e6 / st.mean_us)]);

    // decode-step bookkeeping at full occupancy (slot updates only)
    let cfg = Config::default();
    let mut inst = DecodeInstance::new(160, 160 * 96, 3);
    for r in 0..160 * 96 {
        inst.admit(r as u64, 4096, 1_000_000);
    }
    let st = bench(5, iters(2_000), || {
        cm_infer::benchlib::black_box(inst.step(&cfg.serving));
    });
    t.row(&[format!("decode step bookkeeping ({} slots)", 160 * 96),
            format!("{:.1}", st.mean_us), format!("{:.1}", st.p99_us),
            format!("{:.2e}", 1e6 / st.mean_us)]);

    t.print();

    // end-to-end sim throughput (events/s)
    let trace = generate(&WorkloadSpec::paper_default(2), 400);
    let st = bench(1, iters(10), || {
        let mut sim = ServeSim::new(Config::default(), SimOptions::default(), trace.clone());
        cm_infer::benchlib::black_box(sim.run());
    });
    println!(
        "\nfull PDC sim (400 requests): mean {:.1} ms/run",
        st.mean_us / 1000.0
    );
}
