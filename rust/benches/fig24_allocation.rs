//! Fig. 24: NPU allocation rate vs supernode scale and tightly-coupled
//! block size (§6.1.2), via the block-placement simulation.

use cm_infer::benchlib::{finding, Table};
use cm_infer::topology::alloc::AllocationSim;

fn main() {
    let scales = [224usize, 256, 288, 320, 352, 384];
    let blocks = [5.04f64, 7.56, 10.08, 11.28];

    let mut t = Table::new(
        "Fig 24 — NPU allocation rate (%) vs supernode scale and block size",
        &["Scale \\ mean block", "5.04", "7.56", "10.08", "11.28"],
    );
    let mut rows = Vec::new();
    for &scale in &scales {
        let mut cells = vec![format!("{scale} NPUs")];
        let mut row = Vec::new();
        for &mb in &blocks {
            let stats = AllocationSim {
                supernode_size: scale,
                n_supernodes: 1, // the paper rates a single supernode per scale
                mean_block: mb,
                seed: 42,
            }
            .run(8000);
            cells.push(format!("{:.1}", stats.allocation_rate * 100.0));
            row.push(stats.allocation_rate);
        }
        t.row(&cells);
        rows.push((scale, row));
    }
    t.print();

    let small = rows.first().unwrap();
    let large = rows.last().unwrap();
    finding(&format!(
        "paper shape: larger supernodes allocate better at every block size; at block 11.28 the 384-NPU pool reaches {:.1}% vs {:.1}% for 224 (paper: >94% @10.08/384 vs <91% @224; <85% @11.28/224)",
        large.1[3] * 100.0,
        small.1[3] * 100.0
    ));
    finding("larger blocks pack worse at fixed scale (fragmentation), matching the paper's monotone trend");
}
