//! Table 4: decode throughput per accelerator at the <50 ms TPOT SLO,
//! vs published H800/H100 baselines.

use cm_infer::benchlib::{bench, finding, iters, Table};
use cm_infer::config::{Ascend910cDie, DeepSeekDims};
use cm_infer::simnpu::pipeline::{decode_step, DecodePoint};

fn main() {
    let die = Ascend910cDie::default();
    let m = DeepSeekDims::deepseek_r1();
    let npu_tflops = die.int8_tops * 2.0;

    let published: [(&str, &str, &str, f64, f64, f64); 3] = [
        ("DeepSeek (Blog) on H800", "N/A", "4,989", 50.0, 1850.0, 1979.0),
        ("DeepSeek (Profile) on H800", "128", "4,096", 50.2, 2325.0, 1979.0),
        ("SGLang (Simu. MTP) on H100", "128", "4,000", 55.6, 2172.0, 1979.0),
    ];

    let model = decode_step(&die, &m, &DecodePoint::paper_reference());

    let mut t = Table::new(
        "Table 4 — decode throughput per accelerator (TPOT SLO < 50 ms)",
        &["Method", "Batch", "KV len", "TPOT (ms)", "tokens/s", "tok/s/TFLOPS"],
    );
    for (name, batch, kv, tpot, tput, tflops) in published {
        t.row(&[name.into(), batch.into(), kv.into(), format!("~{tpot:.1}"),
                format!("{tput:.0}"), format!("{:.2}", tput / tflops)]);
    }
    t.row(&[
        "CloudMatrix-Infer [model]".into(),
        "96".into(),
        "4,096".into(),
        format!("{:.1}", model.tpot_ms),
        format!("{:.0}", model.tokens_per_s_per_npu),
        format!("{:.2}", model.tokens_per_s_per_npu / npu_tflops),
    ]);
    t.print();
    finding("paper: 1,943 tokens/s per NPU at TPOT 49.4 ms → 1.29 tok/s/TFLOPS, the best compute efficiency of all systems");
    finding(&format!(
        "model: {:.0} tokens/s per NPU at TPOT {:.1} ms → {:.2} tok/s/TFLOPS",
        model.tokens_per_s_per_npu,
        model.tpot_ms,
        model.tokens_per_s_per_npu / npu_tflops
    ));

    let st = bench(10, iters(50_000), || {
        let v = decode_step(&die, &m, &DecodePoint::paper_reference());
        cm_infer::benchlib::black_box(v.step_us);
    });
    println!("\ndecode-model eval: mean {:.2} µs", st.mean_us);
}
