//! Table 8: MLA operator TFLOPS utilization, compute-bound regime —
//! CANN MLA on Ascend 910C vs DeepSeek FlashMLA on H800.

use cm_infer::benchlib::{finding, Table};
use cm_infer::config::{Ascend910cDie, DeepSeekDims};
use cm_infer::simnpu::ops::mla;

fn main() {
    let die = Ascend910cDie::default();

    let mut t = Table::new(
        "Table 8 — MLA TFLOPS utilization (compute-intensive, BF16)",
        &["Implementation", "Achieved TFLOPS", "Peak TFLOPS", "Utilization"],
    );
    t.row(&[
        "DeepSeek FlashMLA on H800".into(),
        format!("{:.0}", mla::h800::ACHIEVED_TFLOPS),
        format!("{:.0}", mla::h800::PEAK_TFLOPS_BF16),
        format!("{:.1}%", mla::h800::compute_util() * 100.0),
    ]);
    t.row(&[
        "CANN MLA on Ascend 910C die [model]".into(),
        format!("{:.0}", mla::compute_bound_tflops(&die)),
        format!("{:.0}", die.bf16_tflops),
        format!("{:.1}%", die.mla_compute_util * 100.0),
    ]);
    t.print();
    finding("paper shape: comparable utilization (66.7% vs 65.4%) despite 2.6x peak-rate difference — MLA efficiency ports across architectures");

    // derived: a compute-bound prefill-style MLA call through the op model
    let m = DeepSeekDims::deepseek_r1();
    let shape = mla::MlaDecodeShape { batch: 256, q_tokens: 1, kv_len: 8192 };
    let (p, c, o) = mla::decode_mla_us(&die, &m, &shape, 1.0, true);
    println!("\nop-model sanity (batch 256, 8K KV): prolog {p:.0} µs, core {c:.0} µs, out {o:.0} µs");
}
