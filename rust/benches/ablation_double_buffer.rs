//! Ablation (§4.2.1 Opt.3): double-buffered dispatch/combine shared-memory
//! pre-allocation — buffer sizing math and the race the second buffer
//! prevents, plus the memory-overhead accounting the paper reports.

use cm_infer::benchlib::{finding, Table};
use cm_infer::config::DeepSeekDims;

/// Paper Eq. 1–2: buffer_size = rank_num x max_tokens x msg_size,
/// max_tokens = local_batch x min(topK, experts_per_die).
fn buffer_bytes(ranks: usize, local_batch: usize, top_k: usize, experts_per_die: usize,
                msg_bytes: u64) -> u64 {
    let max_tokens = local_batch * top_k.min(experts_per_die.max(1));
    (ranks * max_tokens) as u64 * msg_bytes
}

fn main() {
    let m = DeepSeekDims::deepseek_r1();

    // paper's own worked example: batch 96, <=2 experts/die, 320 ranks
    let dispatch = buffer_bytes(320, 96, m.top_k, 1, 7 * 1024 + 512);
    let combine = buffer_bytes(320, 96, m.top_k, 1, 14 * 1024);
    let mut t = Table::new(
        "Pre-allocated shared-memory buffers (§4.2.1 Opt.3, per die)",
        &["Buffer", "ranks", "max_tokens", "msg KB", "size MB"],
    );
    t.row(&["dispatch".into(), "320".into(), "96".into(), "7.5".into(),
            format!("{:.0}", dispatch as f64 / 1e6)]);
    t.row(&["combine".into(), "320".into(), "96".into(), "14".into(),
            format!("{:.0}", combine as f64 / 1e6)]);
    t.row(&["total (double-buffered pair)".into(), "".into(), "".into(), "".into(),
            format!("{:.0}", (dispatch + combine) as f64 / 1e6)]);
    t.print();
    finding("paper: ~225 MB dispatch + ~420 MB combine ≈ 645 MB per die — modest vs 64 GB HBM");

    // race demonstration: single shared buffer vs double buffering
    // simulate rank skew: a fast rank issues Combine while a slow peer is
    // still consuming its Dispatch payload.
    let mut t = Table::new(
        "Race check — single buffer vs double buffer under rank skew",
        &["Scheme", "writer may overwrite unread dispatch payload?"],
    );
    // with one buffer, combine writes land in the same region: if any peer
    // lags (skew > 0), data is corrupted.
    t.row(&["single shared buffer".into(), "YES — corruption when any rank lags".into()]);
    t.row(&["double buffering (paper)".into(), "no — writers always target the idle buffer".into()]);
    t.print();
    finding("double buffering costs 2x the (modest) buffer memory and removes the dispatch/combine write race entirely — static shapes + static buffers enable the static-graph execution of §4.2.1");

    // static vs dynamic allocation: per-step allocation cost avoided
    let steps_per_s = 1.0 / 0.09; // ~11 decode steps/s at the reference point
    let allocs_avoided_per_s = steps_per_s * 2.0 * m.n_layers as f64;
    println!(
        "\nstatic pre-allocation avoids ~{allocs_avoided_per_s:.0} buffer (re)allocations + CPU-NPU syncs per second per die"
    );
}
