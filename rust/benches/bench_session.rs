//! BENCH_session — session-aware serving: decode throughput vs prefix-
//! cache hit rate.
//!
//! Sweeps the serving loop across session legs that differ only in how
//! much prefix reuse the workload offers and which hot-loop features are
//! armed: `mixed_slo` (no sessions — hit rate pinned at 0), `session_chat`
//! and `agentic_loop` at full feature (cache-affinity routing + MTP), and
//! the two `session_chat` ablations (`--no-cache-affinity`, `--no-mtp`).
//! The headline columns are decode tok/s/NPU against the measured cache
//! hit rate — the Fig 23 story that throughput and TTFT hinge on reuse.
//!
//! Emits `BENCH_session.json` at the repo root (CI uploads it alongside
//! `BENCH_sim_core.json`). `CM_BENCH_QUICK=1` drops to 2 K requests.

use std::collections::BTreeMap;

use cm_infer::benchlib::{finding, quick, Table};
use cm_infer::config::Config;
use cm_infer::coordinator::sim::{ServeSim, SimOptions};
use cm_infer::util::json::Json;
use cm_infer::workload::{generate_scenario, ScenarioSpec};

const SEED: u64 = 42;
const OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_session.json");

struct LegOut {
    leg: &'static str,
    scenario: &'static str,
    hit_rate: f64,
    reprefill: f64,
    mtp_acc: f64,
    tok_s_npu: f64,
    ttft_p50_ms: f64,
    ttft_p99_ms: f64,
}

fn run_leg(
    leg: &'static str,
    scenario: &'static str,
    affinity: bool,
    mtp: bool,
    n: usize,
) -> LegOut {
    let sc = ScenarioSpec::by_name(scenario, SEED).unwrap();
    let trace = generate_scenario(&sc, n);
    let mut cfg = Config::default();
    cfg.serving.tier_slos = sc.tier_slo_configs();
    cfg.serving.mtp = mtp;
    let opts = SimOptions { seed: SEED, cache_affinity: affinity, ..SimOptions::default() };
    let r = ServeSim::new(cfg, opts, trace).run();
    assert_eq!(r.requests_completed, n as u64, "{leg}: dropped requests");
    LegOut {
        leg,
        scenario,
        hit_rate: r.cache_hit_rate,
        reprefill: r.reprefill_frac,
        mtp_acc: r.mtp_acceptance,
        tok_s_npu: r.decode_tokens_per_s_per_npu(),
        ttft_p50_ms: r.ttft_us.p50 / 1e3,
        ttft_p99_ms: r.ttft_us.p99 / 1e3,
    }
}

fn main() {
    let n: usize = if quick() { 2_000 } else { 20_000 };

    let legs = [
        run_leg("no_sessions", "mixed_slo", true, true, n),
        run_leg("chat_no_affinity", "session_chat", false, true, n),
        run_leg("chat_no_mtp", "session_chat", true, false, n),
        run_leg("chat_full", "session_chat", true, true, n),
        run_leg("agentic_full", "agentic_loop", true, true, n),
    ];

    let mut t = Table::new(
        "Session-aware serving — decode tok/s/NPU vs prefix-cache hit rate",
        &["leg", "scenario", "hit rate", "reprefill", "mtp acc", "tok/s/NPU", "ttft p50 ms", "ttft p99 ms"],
    );
    for l in &legs {
        t.row(&[
            l.leg.to_string(),
            l.scenario.to_string(),
            format!("{:.3}", l.hit_rate),
            format!("{:.3}", l.reprefill),
            format!("{:.3}", l.mtp_acc),
            format!("{:.1}", l.tok_s_npu),
            format!("{:.1}", l.ttft_p50_ms),
            format!("{:.1}", l.ttft_p99_ms),
        ]);
    }
    t.print();
    finding("throughput tracks reuse: the session legs' decode tok/s/NPU rises with the cache hit rate, the no-affinity ablation pays UB pool fetches on every warm turn, and the no-MTP ablation gives back the speculative multi-token step");

    let rows: Vec<Json> = legs
        .iter()
        .map(|l| {
            let mut o = BTreeMap::new();
            o.insert("leg".to_string(), Json::Str(l.leg.to_string()));
            o.insert("scenario".to_string(), Json::Str(l.scenario.to_string()));
            o.insert("cache_hit_rate".to_string(), Json::Num(l.hit_rate));
            o.insert("reprefill_frac".to_string(), Json::Num(l.reprefill));
            o.insert("mtp_acceptance".to_string(), Json::Num(l.mtp_acc));
            o.insert("decode_tok_s_per_npu".to_string(), Json::Num(l.tok_s_npu));
            o.insert("ttft_p50_ms".to_string(), Json::Num(l.ttft_p50_ms));
            o.insert("ttft_p99_ms".to_string(), Json::Num(l.ttft_p99_ms));
            Json::Obj(o)
        })
        .collect();
    let mut obj = BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str("session".to_string()));
    obj.insert("seed".to_string(), Json::Num(SEED as f64));
    obj.insert("requests".to_string(), Json::Num(n as f64));
    obj.insert("legs".to_string(), Json::Arr(rows));
    obj.insert("quick".to_string(), Json::Bool(quick()));
    let doc = Json::Obj(obj).to_string();
    match std::fs::write(OUT, &doc) {
        Ok(()) => println!("  -> wrote {OUT}"),
        Err(e) => eprintln!("  -> could not write {OUT}: {e}"),
    }
}
