//! Fig. 21: prefill throughput and per-layer breakdown with and without
//! the AIC/AIV/SDMA microbatch pipeline (§4.3.2).

use cm_infer::benchlib::{finding, Table};
use cm_infer::config::{Ascend910cDie, DeepSeekDims};
use cm_infer::simnpu::pipeline::{prefill_layer, prefill_model, PrefillPoint};

fn main() {
    let die = Ascend910cDie::default();
    let m = DeepSeekDims::deepseek_r1();

    let mut t = Table::new(
        "Fig 21a — prefill throughput w/ and w/o microbatch (16K tok/NPU)",
        &["Prompt len", "tok/s/NPU (off)", "tok/s/NPU (on)", "gain"],
    );
    for prompt in [1024usize, 2048, 4096, 8192] {
        let base = PrefillPoint { prompt_len: prompt, ..PrefillPoint::paper_reference(false) };
        let on = prefill_model(&die, &m, &base);
        let off = prefill_model(&die, &m, &PrefillPoint { microbatch: false, ..base });
        t.row(&[
            format!("{prompt}"),
            format!("{:.0}", off.tokens_per_s_per_npu),
            format!("{:.0}", on.tokens_per_s_per_npu),
            format!("+{:.0}%", (on.tokens_per_s_per_npu / off.tokens_per_s_per_npu - 1.0) * 100.0),
        ]);
    }
    t.print();
    finding("paper shape: +23–31% throughput from overlapping AIV aux work and SDMA transfers with AIC compute; throughput decreases with prompt length (attention quadratic)");

    let base = PrefillPoint::paper_reference(false);
    let on = prefill_layer(&die, &m, &base);
    let off = prefill_layer(&die, &m, &PrefillPoint { microbatch: false, ..base });
    let mut t = Table::new(
        "Fig 21b — per-layer breakdown at 4K prompts (µs per 16K-token batch)",
        &["Component", "w/o microbatch", "with microbatch"],
    );
    for (name, a, b) in [
        ("ATTN+proj (AIC)", off.attn, on.attn),
        ("FFN/MoE (AIC)", off.ffn, on.ffn),
        ("Dispatch/CombineCompute (AIV)", off.aux, on.aux),
        ("All-to-all (SDMA)", off.comm, on.comm),
        ("Overall / layer", off.layer, on.layer),
    ] {
        t.row(&[name.into(), format!("{a:.0}"), format!("{b:.0}")]);
    }
    t.print();
    finding(&format!(
        "paper shape: ~24% per-layer latency cut (model: {:.0}%)",
        (1.0 - on.layer / off.layer) * 100.0
    ));
}
